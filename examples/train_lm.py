"""Train a ~100M-parameter assigned architecture (mamba2-130m) for a few
hundred steps on the synthetic token stream — the LM-side end-to-end
driver.  Defaults are sized for this CPU container; --full uses the real
130M config.

    PYTHONPATH=src python examples/train_lm.py [--full] [--steps 300]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import TrainConfig, get_config, smoke_variant
from repro.data.loader import ShardedLoader
from repro.data.tokens import TokenSource
from repro.metrics import Meter
from repro.models import transformer as tfm
from repro.train import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--full", action="store_true",
                    help="use the full config (slow on CPU)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum-steps", type=int, default=1,
                    help="gradient-accumulation microbatches per step")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        # mid-size: keeps the family but fits a CPU training budget
        cfg = cfg.replace(num_layers=max(4, cfg.num_layers // 4),
                          vocab_size=min(cfg.vocab_size, 8192))
    params = tfm.init(cfg, jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params, {args.steps} steps, "
          f"batch {args.batch} x seq {args.seq}")

    tc = TrainConfig(learning_rate=6e-4, total_steps=args.steps,
                     warmup_steps=args.steps // 10, remat="block")
    # The unified engine: mesh-sharded via the logical-axis rules, state
    # donated through the jitted step, microbatched when --accum-steps > 1.
    # The ShardedLoader assembles + device_puts token batches two steps
    # ahead on a background thread (paper Fig. 2a "I.P.").
    engine = Engine.for_lm(cfg, tc, accum_steps=args.accum_steps)
    state = engine.init_state(jax.random.key(0), params)

    meter = Meter()
    loader = ShardedLoader(TokenSource(cfg, args.batch, args.seq),
                           engine, prefetch=2, num_steps=args.steps)
    for b in loader:
        state, m = engine.step(state, b)
        meter.update(loss=float(m["loss"]))
        i = loader.cursor - 1
        if i % max(args.steps // 15, 1) == 0:
            print(f"step {i:4d}  loss {meter.last('loss'):.4f}  "
                  f"({meter.elapsed():.0f}s)", flush=True)
    print(f"done: loss {meter.last('loss'):.4f} "
          f"(start {meter._vals['loss'][0]:.4f}) in {meter.elapsed():.0f}s")


if __name__ == "__main__":
    main()
