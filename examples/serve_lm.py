"""Batched serving demo: continuous batching through the sharded inference
engine on a reduced config of each decodable family (dense / MoE / SSM /
hybrid / VLM) — ragged prompts, EOS-free budgeted generation, slot reuse,
the paged KV cache with chunked prefill (the serving default), and
SPECULATIVE DECODING: `--spec-k 3 --drafter ngram` drafts three tokens per
slot with checkpoint-free prompt lookup and verifies them in one fused
paged forward.  Greedy serving is lossless under speculation, so the demo
streams are bit-identical to a `spec_k = 0` run — acceptance only changes
how many tokens each fused step yields (see `spec_accepted` /
`accepted_tok_per_step` in the emitted JSON).

The second half is a SHARED-SYSTEM-PROMPT workload: every request opens
with the same 24-token prefix, served with the refcounted radix prefix
cache and page-aware preemption on (`--prefix-cache --preempt`).  Later
requests map the cached prefix pages by refcount bump and skip that
prefill entirely — the demo prints the resulting hit rate.  Streams
stay bit-identical to an uncached run; the cache buys latency, not
different tokens.

    PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve


class Args:
    smoke = True
    requests = 6
    batch_size = 3
    prompt_len = 16
    gen = 12
    max_len = 0
    page_size = 8          # paged KV pool (0 = contiguous slot-major cache)
    num_pages = 0          # 0 = slots * ceil(max_len / page_size)
    prefill_chunk = 8      # admit prompts 8 tokens at a time between decodes
    spec_k = 3             # draft-and-verify: up to 3 drafts per fused step
    drafter = "ngram"      # prompt-lookup drafts ("model": second engine,
    draft_config = ""      #   --draft-config names its smaller arch)
    draft_ckpt = ""
    eos = -1
    ragged = True
    ckpt = ""
    seed = 0
    prefix_cache = False   # refcounted radix prefix cache over the page pool
    preempt = False        # page-aware preemption instead of defer-only
    shared_prefix = 0      # tokens shared by every prompt (system prompt)


def main():
    for arch in ("qwen2-1.5b", "deepseek-moe-16b", "mamba2-130m",
                 "recurrentgemma-2b", "gemma2-2b"):
        a = Args()
        a.arch = arch
        print(f"--- {arch} (reduced config) ---")
        serve(a)

    # Shared-system-prompt workload: 75% of every prompt is a common
    # prefix; the radix cache skips its prefill for every request after
    # the first, and preemption keeps admission moving under page
    # pressure.  Recurrent archs exercise the snapshot-replay path.
    for arch in ("qwen2-1.5b", "recurrentgemma-2b"):
        a = Args()
        a.arch = arch
        a.prompt_len = 32
        a.shared_prefix = 24
        a.prefix_cache = True
        a.preempt = True
        a.ragged = False       # uniform lengths keep the prefix aligned
        a.spec_k = 0
        print(f"--- {arch} + shared system prompt (prefix cache) ---")
        out = serve(a)
        print(f"prefix-cache hit rate: {out['prefix_hit_rate']:.0%} "
              f"({out['prefix_hit_tokens']} prefill tokens skipped, "
              f"{out['prefix_hits']} hits, {out['cow_pages']} CoW pages, "
              f"{out['preemptions']} preemptions)")


if __name__ == "__main__":
    main()
