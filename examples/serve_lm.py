"""Batched serving demo: continuous batching through the sharded inference
engine on a reduced config of each decodable family (dense / MoE / SSM /
hybrid / VLM) — ragged prompts, EOS-free budgeted generation, slot reuse,
and the paged KV cache with chunked prefill (the serving default).

    PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve


class Args:
    smoke = True
    requests = 6
    batch_size = 3
    prompt_len = 16
    gen = 12
    max_len = 0
    page_size = 8          # paged KV pool (0 = contiguous slot-major cache)
    num_pages = 0          # 0 = slots * ceil(max_len / page_size)
    prefill_chunk = 8      # admit prompts 8 tokens at a time between decodes
    eos = -1
    ragged = True
    ckpt = ""
    seed = 0


def main():
    for arch in ("qwen2-1.5b", "deepseek-moe-16b", "mamba2-130m",
                 "recurrentgemma-2b", "gemma2-2b"):
        a = Args()
        a.arch = arch
        print(f"--- {arch} (reduced config) ---")
        serve(a)


if __name__ == "__main__":
    main()
