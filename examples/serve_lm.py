"""Batched serving demo: continuous batching through the sharded inference
engine on a reduced config of each decodable family (dense / MoE / SSM /
hybrid / VLM) — ragged prompts, EOS-free budgeted generation, slot reuse,
the paged KV cache with chunked prefill (the serving default), and
SPECULATIVE DECODING: `--spec-k 3 --drafter ngram` drafts three tokens per
slot with checkpoint-free prompt lookup and verifies them in one fused
paged forward.  Greedy serving is lossless under speculation, so the demo
streams are bit-identical to a `spec_k = 0` run — acceptance only changes
how many tokens each fused step yields (see `spec_accepted` /
`accepted_tok_per_step` in the emitted JSON).

    PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve


class Args:
    smoke = True
    requests = 6
    batch_size = 3
    prompt_len = 16
    gen = 12
    max_len = 0
    page_size = 8          # paged KV pool (0 = contiguous slot-major cache)
    num_pages = 0          # 0 = slots * ceil(max_len / page_size)
    prefill_chunk = 8      # admit prompts 8 tokens at a time between decodes
    spec_k = 3             # draft-and-verify: up to 3 drafts per fused step
    drafter = "ngram"      # prompt-lookup drafts ("model": second engine,
    draft_config = ""      #   --draft-config names its smaller arch)
    draft_ckpt = ""
    eos = -1
    ragged = True
    ckpt = ""
    seed = 0


def main():
    for arch in ("qwen2-1.5b", "deepseek-moe-16b", "mamba2-130m",
                 "recurrentgemma-2b", "gemma2-2b"):
        a = Args()
        a.arch = arch
        print(f"--- {arch} (reduced config) ---")
        serve(a)


if __name__ == "__main__":
    main()
