"""Quickstart: the paper's Dom-ST model on one synthetic watershed.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_config
from repro.core import domst
from repro.data import generate_watershed, make_training_windows
from repro.data.pipeline import train_test_split
from repro.optim import make_optimizer


def main():
    # 1. data: pixellated precipitation + distance prior + discharge labels
    ws = generate_watershed(0, num_days=365)
    windows = make_training_windows(ws, window=30)
    train, test = train_test_split(windows)
    print(f"watershed 0: {windows.precip.shape[0]} windows, "
          f"{windows.precip.shape[2]} pixels")

    # 2. model: Pix-Con -> partitioned multihead CNN -> stacked LSTM (+P)
    cfg = get_config("domst")
    params = domst.init(cfg, jax.random.key(0))
    print(f"params: {sum(x.size for x in jax.tree.leaves(params)):,}")

    # 3. train
    tc = TrainConfig(learning_rate=3e-3, total_steps=300, warmup_steps=10)
    step = domst.make_train_step(cfg, tc)
    opt = make_optimizer(tc)[0](params)
    rng = np.random.default_rng(0)
    n = len(train["discharge"])
    for i in range(300):
        sl = rng.integers(0, n, 64)
        batch = {k: jnp.asarray(v[sl]) for k, v in train.items()}
        params, opt, m = step(params, opt, batch)
        if i % 50 == 0:
            print(f"step {i:4d}  mse {float(m['loss']):.4f}")

    # 4. evaluate with the paper's metric (Nash–Sutcliffe efficiency)
    ev = domst.evaluate(params, cfg,
                        {k: jnp.asarray(v) for k, v in test.items()})
    print(f"test NSE = {float(ev['nse']):.3f}  (1.0 = perfect, "
          f"0.0 = predicting the mean)")


if __name__ == "__main__":
    main()
