"""End-to-end driver (paper's kind): distributed multi-watershed flood
training — all 23 watershed replicas trained via the IP-D pipeline
(watershed axis == mesh data axis on TPU; vectorized on CPU), a few
hundred steps, NSE per watershed + ablation vs the Singlehead baseline.

    PYTHONPATH=src python examples/train_flood.py [--watersheds 23]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import TrainConfig, get_config
from repro.core import domst
from repro.data import generate_all_watersheds, make_training_windows
from repro.data.loader import ShardedLoader
from repro.data.pipeline import (
    InputPipeline, StackedSource, stacked_test_batch, train_split,
)
from repro.train import Engine


def train_stacked(cfg_name, windows, ip, epochs):
    cfg = get_config(cfg_name)
    tc = TrainConfig(learning_rate=3e-3, total_steps=epochs * 60,
                     warmup_steps=20)
    # The unified engine: stacked/IP-D mode vmaps the step over the leading
    # watershed axis and shards it over the mesh "data"/"pod" axes; the
    # TrainState (params + opt moments + rng) is donated through the step.
    # The ShardedLoader prefetches device-placed batches two steps ahead so
    # the step never waits on host windowing (paper Fig. 2a "I.P.").
    engine = Engine.for_domst(cfg, tc, stacked=True)
    state = engine.init_state(
        jax.random.key(0),
        domst.init_stacked(cfg, jax.random.key(0), len(windows)))
    source = StackedSource(ip)
    loader = ShardedLoader(source, engine, prefetch=2,
                           num_steps=epochs * source.steps_per_epoch)
    for b in loader:
        state, m = engine.step(state, b)
    # held-out NSE per watershed straight off the sharded state (vmapped
    # eval_step) — params never come back to host
    ev = engine.eval_step(state, engine.place_batch(stacked_test_batch(windows)))
    return np.asarray(ev["nse"]), int(state.step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--watersheds", type=int, default=23)
    ap.add_argument("--days", type=int, default=400)
    ap.add_argument("--epochs", type=int, default=6)
    args = ap.parse_args()

    data = generate_all_watersheds(args.watersheds, num_days=args.days)
    windows = [make_training_windows(w) for w in data.values()]
    # train on the leading split; eval_step scores the held-out tail
    ip = InputPipeline([train_split(w) for w in windows], batch_size=64)
    print(f"{len(windows)} watersheds (paper: 23), {args.epochs} epochs, "
          f"IP-D stacked execution")

    t0 = time.perf_counter()
    nse_dom, steps = train_stacked("domst", windows, ip, args.epochs)
    t_dom = time.perf_counter() - t0
    print(f"Dom-ST:      {steps} steps in {t_dom:.1f}s  "
          f"mean NSE {nse_dom.mean():.3f}  min {nse_dom.min():.3f}  "
          f"max {nse_dom.max():.3f}")

    t0 = time.perf_counter()
    nse_sh, _ = train_stacked("domst-singlehead", windows, ip, args.epochs)
    t_sh = time.perf_counter() - t0
    print(f"Singlehead:  mean NSE {nse_sh.mean():.3f}  ({t_sh:.1f}s)")
    better = (nse_dom > nse_sh).mean() * 100
    print(f"Dom-ST beats Singlehead on {better:.0f}% of watersheds "
          f"(paper: 'almost all')")


if __name__ == "__main__":
    main()
