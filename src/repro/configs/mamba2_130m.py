"""Mamba2-130M — SSD state-space model [arXiv:2405.21060].

24L d_model=768 (attention-free) vocab=50280, ssm_state=128.
d_inner = 2*768 = 1536, head_dim 64 -> 24 SSD heads.
Sub-quadratic: runs long_500k decode.
"""
from repro.configs.base import SSM, ModelConfig, SSMConfig, register


@register("mamba2-130m")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        num_layers=24,
        d_model=768,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        layer_pattern=(SSM,),
        norm="rmsnorm",
        act="silu",
        rope=False,
        tie_embeddings=True,
        ssm=SSMConfig(
            state_dim=128,
            head_dim=64,
            expand=2,
            conv_width=4,
            chunk_size=256,
            ngroups=1,
        ),
        tp_mode="heads",          # shard SSD heads (24 -> padded on 16-way axis)
        source="arXiv:2405.21060",
    )
