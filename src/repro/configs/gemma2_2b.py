"""Gemma2-2B — local/global alternating attention + logit softcaps [arXiv:2408.00118].

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000, window 4096,
attention softcap 50, final-logit softcap 30, pre+post sandwich norms,
sqrt(d_model) embedding scaling.

8 heads on a 16-way model axis -> tp_mode="ffn" (9216/16 = 576).
long_500k runs only under the ``local_only`` variant (global layers
switched to window-4096 sliding attention) — a documented deviation,
not the published config (DESIGN.md §5).
"""
from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, ModelConfig, register


@register("gemma2-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b",
        family="dense",
        num_layers=26,
        d_model=2304,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab_size=256000,
        layer_pattern=(ATTN_LOCAL, ATTN_GLOBAL),
        window=4096,
        norm="rmsnorm",
        act="gelu",
        rope=True,
        rope_theta=10000.0,
        tie_embeddings=True,
        embed_scale=True,
        logit_softcap=30.0,
        attn_softcap=50.0,
        post_norms=True,
        tp_mode="ffn",
        source="arXiv:2408.00118",
    )


@register("gemma2-2b-localonly")
def config_local_only() -> ModelConfig:
    """Sliding-window-only variant for the long_500k shape (sub-quadratic)."""
    return config().replace(
        name="gemma2-2b-localonly",
        layer_pattern=(ATTN_LOCAL,),
        notes="long-context variant: all layers local window=4096",
    )
