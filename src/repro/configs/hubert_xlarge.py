"""HuBERT X-Large — audio encoder backbone [arXiv:2106.07447].

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (k-means codebook units).
Encoder-only (bidirectional); same transformer arch as wav2vec 2.0 XL.
The conv waveform feature extractor is a STUB per the assignment carve-out:
``input_specs`` supplies precomputed 512-dim frame embeddings and the model
owns only the frame projection + transformer + unit-prediction head.
HuBERT has no decode step (encoder-only) — decode shapes are skipped
(DESIGN.md §5).
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig, register


@register("hubert-xlarge")
def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge",
        family="audio",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab_size=504,
        layer_pattern=(ATTN_GLOBAL,),
        norm="layernorm",
        act="gelu",
        qkv_bias=True,            # fairseq MHA uses biases
        rope=False,               # HuBERT uses conv pos-emb; stubbed as learned-abs
        causal=False,
        tie_embeddings=False,
        frontend="audio_stub",
        frontend_dim=512,         # conv feature extractor output dim (stub)
        tp_mode="heads",          # 16 heads / 16-way model axis
        source="arXiv:2106.07447",
        notes="encoder-only; masked unit prediction objective",
    )
