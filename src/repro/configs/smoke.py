"""Reduced smoke-test variants: 2 layers, d_model<=512, <=4 experts.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation); CPU smoke tests instantiate these reduced variants of the same
family and run one forward/train step.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (
    DomSTConfig, ModelConfig, MoEConfig, PixConConfig, RGLRUConfig, SSMConfig,
)


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Shrink ``cfg`` to a CPU-runnable variant of the same family."""
    if cfg.family == "domst":
        return cfg.replace(
            name=cfg.name + "-smoke",
            domst=dataclasses.replace(
                cfg.domst,
                num_pixels=16, window_days=8, cnn_channels=8,
                lstm_hidden=16, lstm_layers=2, mlp_hidden=16,
                num_heads=min(cfg.domst.num_heads, 2),
                pixcon=PixConConfig(hidden=8, num_partitions=2),
            ),
        )

    d_model = min(cfg.d_model, 256)
    # keep head structure: shrink head count but preserve GQA ratio
    if cfg.num_heads:
        n_heads = max(2, min(4, cfg.num_heads))
        ratio = max(1, cfg.num_heads // max(cfg.num_kv_heads, 1))
        n_kv = max(1, n_heads // ratio)
        head_dim = max(8, d_model // n_heads)
    else:
        n_heads = n_kv = head_dim = 0

    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=d_model,
        num_heads=n_heads,
        num_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512) if cfg.vocab_size else 0,
        window=min(cfg.window, 16),
        first_k_dense=min(cfg.first_k_dense, 1),
        num_patches=min(cfg.num_patches, 8) if cfg.num_patches else 0,
        frontend_dim=min(cfg.frontend_dim, 64) if cfg.frontend_dim else 0,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64,
            num_shared=min(cfg.moe.num_shared, 1),
            d_ff_shared=64 if cfg.moe.num_shared else 0,
        )
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=16, head_dim=32, chunk_size=8)
    if cfg.rglru is not None:
        kw["rglru"] = dataclasses.replace(cfg.rglru, lru_width=d_model)
    # keep the layer pattern (family behaviour) but only 2 layers:
    # take the first 2 kinds so hybrids still exercise both paths when the
    # pattern allows it.
    kinds = cfg.layer_kinds()[:2] if cfg.num_layers >= 2 else cfg.layer_pattern
    # ensure hybrids exercise both recurrent and attention paths
    uniq = tuple(dict.fromkeys(cfg.layer_pattern))
    if len(uniq) > 1:
        kinds = uniq[:2]
    kw["layer_pattern"] = tuple(kinds)
    return cfg.replace(**kw)
