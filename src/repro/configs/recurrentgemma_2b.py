"""RecurrentGemma-2B — Griffin hybrid: RG-LRU + local attention 2:1 [arXiv:2402.19427].

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000, window 2048.
Layer pattern (recurrent, recurrent, local) repeating.  Sub-quadratic
(no global attention) -> runs long_500k decode.
"""
from repro.configs.base import ATTN_LOCAL, RECURRENT, ModelConfig, RGLRUConfig, register


@register("recurrentgemma-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        num_layers=26,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        layer_pattern=(RECURRENT, RECURRENT, ATTN_LOCAL),
        window=2048,
        norm="rmsnorm",
        act="gelu",               # gated-GELU MLP
        rope=True,
        rope_theta=10000.0,
        tie_embeddings=True,
        embed_scale=True,
        rglru=RGLRUConfig(lru_width=2560, conv_width=4),
        tp_mode="ffn",            # 10 heads not divisible by 16 -> shard ffn/lru
        source="arXiv:2402.19427",
    )
