"""Config registry.  Importing this package registers every architecture."""
from repro.configs.base import (  # noqa: F401
    ATTN_GLOBAL, ATTN_LOCAL, RECURRENT, SSM,
    DomSTConfig, INPUT_SHAPES, ModelConfig, MoEConfig, PixConConfig,
    RGLRUConfig, SSMConfig, ShapeConfig, TrainConfig,
    get_config, list_configs, register,
)

# one module per assigned architecture (+ the paper's own model)
from repro.configs import (  # noqa: F401
    domst,
    hubert_xlarge,
    olmo_1b,
    internvl2_2b,
    deepseek_moe_16b,
    llama3_2_3b,
    qwen3_moe_30b_a3b,
    mamba2_130m,
    recurrentgemma_2b,
    qwen2_1_5b,
    gemma2_2b,
)
from repro.configs.smoke import smoke_variant  # noqa: F401

ASSIGNED_ARCHS = (
    "hubert-xlarge",
    "olmo-1b",
    "internvl2-2b",
    "deepseek-moe-16b",
    "llama3.2-3b",
    "qwen3-moe-30b-a3b",
    "mamba2-130m",
    "recurrentgemma-2b",
    "qwen2-1.5b",
    "gemma2-2b",
)
