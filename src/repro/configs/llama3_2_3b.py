"""Llama-3.2-3B — small llama3 dense decoder [hf:meta-llama/Llama-3.2-1B family].

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.
24 heads don't divide the 16-way model axis (and pjit argument shardings
require exact divisibility), so tensor parallelism shards d_ff
(8192/16 = 512) and the KV-cache seq axis instead; see DESIGN.md §4.
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig, register


@register("llama3.2-3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b",
        family="dense",
        num_layers=28,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=128256,
        layer_pattern=(ATTN_GLOBAL,),
        norm="rmsnorm",
        act="silu",
        rope=True,
        rope_theta=500_000.0,
        tie_embeddings=True,
        tp_mode="ffn",
        source="hf:meta-llama/Llama-3.2-3B",
    )
