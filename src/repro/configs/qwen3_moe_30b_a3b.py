"""Qwen3-30B-A3B — 128-expert MoE, top-8 [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4) vocab=151936; MoE 128 routed experts top-8,
expert hidden 768, no shared experts.  Qwen3 uses QK-RMSNorm and no QKV bias.
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig, MoEConfig, register


@register("qwen3-moe-30b-a3b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-30b-a3b",
        family="moe",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=4,
        head_dim=128,
        d_ff=768,                 # == expert hidden (no dense layers)
        vocab_size=151936,
        layer_pattern=(ATTN_GLOBAL,),
        norm="rmsnorm",
        act="silu",
        rope=True,
        rope_theta=1_000_000.0,
        qk_norm=True,
        tie_embeddings=False,
        moe=MoEConfig(
            num_experts=128,
            top_k=8,
            d_ff_expert=768,
            num_shared=0,
            aux_loss_coef=0.001,
        ),
        tp_mode="heads",          # 32 heads / 16-way axis
        source="hf:Qwen/Qwen3-30B-A3B",
    )
