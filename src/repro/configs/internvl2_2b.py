"""InternVL2-2B — VLM: InternViT-300M + InternLM2-1.8B LM [arXiv:2404.16821].

Assigned backbone (the LM): 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553.  The vision encoder + MLP projector are a STUB per the
assignment carve-out: ``input_specs`` supplies ``num_patches`` precomputed
1024-dim patch embeddings per image; the model owns the projector
(1024 -> d_model) and the language decoder that consumes them.
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig, register


@register("internvl2-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        family="vlm",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=92553,
        layer_pattern=(ATTN_GLOBAL,),
        norm="rmsnorm",
        act="silu",
        rope=True,
        rope_theta=1_000_000.0,
        tie_embeddings=False,
        frontend="vision_stub",
        frontend_dim=1024,        # InternViT feature dim (stub)
        num_patches=256,          # patch tokens per image prepended to text
        tp_mode="heads",
        source="arXiv:2404.16821",
    )
