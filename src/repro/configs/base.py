"""Configuration system for the repro framework.

Every architecture (the paper's Dom-ST plus the 10 assigned public
architectures) is described by a frozen dataclass tree.  Configs are pure
data: they never touch jax device state, so importing a config is always
safe inside tests / the dry-run launcher.

Layer heterogeneity (gemma2's local/global alternation, recurrentgemma's
rec/rec/attn pattern) is expressed with ``layer_pattern``: a tuple of layer
kinds that repeats to cover ``num_layers``.  The transformer stack scans
over full pattern repetitions and unrolls the remainder.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Mapping, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Layer kinds understood by models/transformer.py
# ---------------------------------------------------------------------------
ATTN_GLOBAL = "global"          # full (causal or bidirectional) attention
ATTN_LOCAL = "local"            # sliding-window attention
RECURRENT = "recurrent"         # RG-LRU recurrent block (recurrentgemma)
SSM = "ssm"                     # Mamba-2 SSD block

LAYER_KINDS = (ATTN_GLOBAL, ATTN_LOCAL, RECURRENT, SSM)


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration (DeepSeekMoE / Qwen3-MoE style)."""

    num_experts: int                  # routed experts
    top_k: int                        # experts per token
    d_ff_expert: int                  # hidden dim of each routed expert
    num_shared: int = 0               # always-on shared experts
    d_ff_shared: int = 0              # hidden dim of shared expert(s); 0 -> d_ff_expert * num_shared
    aux_loss_coef: float = 0.01       # load-balance auxiliary loss
    capacity_factor: float = 1.25     # expert capacity slack (tokens dropped beyond)
    router_dtype: str = "float32"     # router math in fp32 for stability

    def __post_init__(self) -> None:
        if self.top_k > self.num_experts:
            raise ValueError(
                f"top_k={self.top_k} > num_experts={self.num_experts}")


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block configuration [arXiv:2405.21060]."""

    state_dim: int = 128              # N: SSM state size per head
    head_dim: int = 64                # P: channels per SSD head
    expand: int = 2                   # d_inner = expand * d_model
    conv_width: int = 4               # causal depthwise conv kernel width
    chunk_size: int = 256             # SSD chunk length (dual form)
    ngroups: int = 1                  # B/C groups (GQA-analog for SSM)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class RGLRUConfig:
    """RG-LRU recurrent block configuration (RecurrentGemma / Griffin)."""

    lru_width: int = 0                # 0 -> d_model (griffin uses ~4/3 d_model)
    conv_width: int = 4               # temporal conv in the recurrent block
    c_constant: float = 8.0           # the fixed `c` in a = exp(-c * softplus(Λ) * r)


@dataclass(frozen=True)
class PixConConfig:
    """Pix-Con: the paper's pixel-contribution block.

    ``num_partitions`` is the partitioning module's device-facing split of
    pixels by contribution score (paper Fig. 1b); partitions map onto the
    spatial block's heads.
    """

    prior_channels: int = 1           # domain prior channels (distance map)
    hidden: int = 32                  # contribution MLP hidden width
    num_partitions: int = 4           # dynamic pixel partitions (== spatial heads)
    normalize: bool = True            # normalize contribution weights over pixels
    temperature: float = 1.0


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    ``family`` selects the top-level model builder:
      dense | moe | ssm | hybrid | encoder | vlm | audio | domst
    Families vlm/audio use the same decoder/encoder stacks but take
    precomputed patch/frame embeddings (frontend stub per assignment).
    """

    name: str
    family: str
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0                 # 0 -> d_model // num_heads
    d_ff: int = 0
    vocab_size: int = 0

    # stack details
    layer_pattern: Tuple[str, ...] = (ATTN_GLOBAL,)
    window: int = 4096                # sliding window for ATTN_LOCAL
    norm: str = "rmsnorm"             # rmsnorm | layernorm | nonparam_ln
    act: str = "silu"                 # silu | gelu
    qkv_bias: bool = False            # qwen2-style
    qk_norm: bool = False             # qwen3-style QK-RMSNorm
    tie_embeddings: bool = True
    rope_theta: float = 10000.0
    rope: bool = True
    logit_softcap: float = 0.0        # gemma2 final-logit softcap
    attn_softcap: float = 0.0         # gemma2 attention-logit softcap
    post_norms: bool = False          # gemma2 pre+post sandwich norms
    embed_scale: bool = False         # gemma-style sqrt(d_model) embed scaling
    causal: bool = True               # False for encoder-only (hubert)

    # sub-configs
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    pixcon: Optional[PixConConfig] = None
    domst: Optional["DomSTConfig"] = None
    first_k_dense: int = 0            # deepseek-moe: first k layers use dense FFN

    # modality frontends (assignment carve-out: stubs provide embeddings)
    frontend: Optional[str] = None    # None | "audio_stub" | "vision_stub"
    frontend_dim: int = 0             # raw embedding dim fed by the stub
    num_patches: int = 0              # vlm: image patch tokens per example

    # optional generalized contribution gate (paper technique on LM archs)
    contribution_gate: bool = False

    # sharding preference: "heads" (Megatron head TP) or "ffn" (fallback
    # when num_heads doesn't divide the model axis)
    tp_mode: str = "heads"

    source: str = ""                  # citation (arXiv / hf card)
    notes: str = ""

    def padded_vocab(self, multiple: int = 128) -> int:
        """Vocab rounded up so the embedding shards on the model axis
        (Megatron-style vocab padding); padded logit columns are masked
        to -inf in unembed."""
        v = self.vocab_size
        return ((v + multiple - 1) // multiple) * multiple

    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer kind list, pattern repeated/truncated to num_layers."""
        pat = self.layer_pattern
        reps = (self.num_layers + len(pat) - 1) // len(pat)
        return tuple((pat * reps)[: self.num_layers])

    def supports_decode(self) -> bool:
        return self.causal and self.family not in ("encoder", "audio", "domst")

    def sub_quadratic(self) -> bool:
        """True if no layer needs a full-context KV cache (long_500k gate)."""
        kinds = set(self.layer_kinds())
        return ATTN_GLOBAL not in kinds

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class DomSTConfig:
    """The paper's Dom-ST model (Fig. 1): Pix-Con + spatial + temporal."""

    num_pixels: int = 64              # pixels per watershed grid (flattened)
    window_days: int = 30             # trailing days of precipitation (T)
    num_heads: int = 4                # parallel CNN heads (one per device in paper)
    cnn_channels: int = 32            # channels per head
    kernel_size: int = 3
    lstm_hidden: int = 64
    lstm_layers: int = 2              # stacked LSTM (paper: stacked layers)
    mlp_hidden: int = 64
    use_pixcon: bool = True
    use_target_day: bool = True       # the (+P) input
    pixcon: PixConConfig = field(default_factory=PixConConfig)


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 1e-3
    weight_decay: float = 0.0
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"          # cosine | linear | constant
    grad_clip: float = 1.0
    optimizer: str = "adamw"          # adamw | sgd
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    dtype: str = "bfloat16"           # compute dtype
    param_dtype: str = "float32"
    remat: str = "none"               # none | block | full
    grad_accum: int = 1               # microbatches per step (activation memory / A)
    fsdp: bool = False                # ZeRO-style param/opt sharding over data axes
    seed: int = 0


@dataclass(frozen=True)
class ShapeConfig:
    """One of the 4 assigned input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


INPUT_SHAPES: Mapping[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    import repro.configs as _pkg  # noqa: F401  (triggers per-arch module imports)
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_configs() -> Sequence[str]:
    import repro.configs as _pkg  # noqa: F401
    return sorted(_REGISTRY)
