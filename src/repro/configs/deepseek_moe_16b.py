"""DeepSeekMoE-16B — fine-grained MoE [arXiv:2401.06066].

28L d_model=2048 16H (kv=16) vocab=102400; 2 shared + 64 routed experts,
top-6, expert hidden 1408 (fine-grained expert segmentation).  The first
layer uses a dense FFN (d_ff=10944) as in the released model.
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig, MoEConfig, register


@register("deepseek-moe-16b")
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=10944,               # dense FFN width for the first_k_dense layers
        vocab_size=102400,
        layer_pattern=(ATTN_GLOBAL,),
        norm="rmsnorm",
        act="silu",
        rope=True,
        tie_embeddings=False,
        first_k_dense=1,
        moe=MoEConfig(
            num_experts=64,
            top_k=6,
            d_ff_expert=1408,
            num_shared=2,
            d_ff_shared=2816,     # 2 shared experts x 1408
            aux_loss_coef=0.01,
        ),
        tp_mode="heads",
        source="arXiv:2401.06066",
    )
