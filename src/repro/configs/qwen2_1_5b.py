"""Qwen2-1.5B — dense GQA decoder with QKV bias [arXiv:2407.10671].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
12 heads don't divide the 16-way model axis -> tp_mode="ffn"
(8960 / 16 = 560); heads replicated (DESIGN.md §4).
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig, register


@register("qwen2-1.5b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b",
        family="dense",
        num_layers=28,
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab_size=151936,
        layer_pattern=(ATTN_GLOBAL,),
        norm="rmsnorm",
        act="silu",
        qkv_bias=True,
        rope=True,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        tp_mode="ffn",
        source="arXiv:2407.10671",
    )
