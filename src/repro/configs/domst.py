"""Dom-ST — the paper's domain-aware distributed spatiotemporal network.

Pix-Con block + multihead multichannel 1D-CNN spatial block + stacked-LSTM
temporal block with target-day precipitation (+P) injection (Fig. 1).
"""
from repro.configs.base import DomSTConfig, ModelConfig, PixConConfig, register


@register("domst")
def config() -> ModelConfig:
    return ModelConfig(
        name="domst",
        family="domst",
        causal=False,
        domst=DomSTConfig(
            num_pixels=64,
            window_days=30,
            num_heads=4,
            cnn_channels=32,
            kernel_size=3,
            lstm_hidden=64,
            lstm_layers=2,
            mlp_hidden=64,
            use_pixcon=True,
            use_target_day=True,
            pixcon=PixConConfig(num_partitions=4),
        ),
        source="Sarkar, Lu, Jannesari 2023 (this paper)",
    )


@register("domst-singlehead")
def config_singlehead() -> ModelConfig:
    """Paper baseline: single-head CNN, no Pix-Con, no (+P)."""
    base = config()
    return base.replace(
        name="domst-singlehead",
        domst=DomSTConfig(
            num_pixels=64, window_days=30, num_heads=1, cnn_channels=32,
            kernel_size=3, lstm_hidden=64, lstm_layers=2, mlp_hidden=64,
            use_pixcon=False, use_target_day=False,
        ),
    )


@register("domst-singlehead-p")
def config_singlehead_p() -> ModelConfig:
    """Paper baseline: Singlehead(+P) — adds target-day precipitation."""
    base = config()
    return base.replace(
        name="domst-singlehead-p",
        domst=DomSTConfig(
            num_pixels=64, window_days=30, num_heads=1, cnn_channels=32,
            kernel_size=3, lstm_hidden=64, lstm_layers=2, mlp_hidden=64,
            use_pixcon=False, use_target_day=True,
        ),
    )
