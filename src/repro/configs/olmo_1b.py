"""OLMo-1B — dense decoder with non-parametric LayerNorm [arXiv:2402.00838].

16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304.
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig, register


@register("olmo-1b")
def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b",
        family="dense",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=8192,
        vocab_size=50304,
        layer_pattern=(ATTN_GLOBAL,),
        norm="nonparam_ln",       # OLMo: LayerNorm without learnable affine
        act="silu",
        rope=True,
        rope_theta=10000.0,
        tie_embeddings=True,
        tp_mode="heads",
        source="arXiv:2402.00838",
    )
