from repro.serve.engine import InferenceEngine  # noqa: F401
from repro.serve.forecast import Forecaster  # noqa: F401
from repro.serve.sampling import SamplingParams, stream_digest  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    PagePool, RadixPagePool, Request, Scheduler,
)
from repro.serve.speculative import (  # noqa: F401
    Drafter, ModelDrafter, NgramDrafter,
)
from repro.serve.state import (  # noqa: F401
    InferenceState, inference_state_axes, new_inference_state,
    new_paged_inference_state, paged_inference_state_axes,
)
