"""InferenceState: the single pytree the inference engine owns.

Mirror of ``repro.train.state``: everything a serving replica needs —
model parameters, the slot-major decode cache (KV rings for attention
layers, recurrent/conv state for RG-LRU and SSD layers) and the per-slot
position counters — travels through the jitted prefill-insert and decode
steps as one donated pytree, sharded by one structurally-matched
logical-spec tree resolved from the ``distributed/sharding.py`` rule
tables (the ``cache_seq`` axis takes the ``cache_needs_seq_shard``
branch so a long cache never replicates across the model axis).

The leading axis of every cache leaf is the REQUEST SLOT axis (logical
``batch`` -> the data/pod mesh axes): continuous batching allocates a
slot per admitted request and evicts it on EOS, so slots are recycled
in place with a scatter — the state never changes shape.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import is_axes
from repro.models import transformer as tfm


class InferenceState(NamedTuple):
    params: Any
    cache: Any            # tfm.init_cache pytree, slot-major leading axis
    positions: jax.Array  # (S,) int32: next write index per slot
    last_tok: jax.Array   # (S,) int32: last accepted/emitted token per slot


def inference_state_axes(cfg: ModelConfig) -> InferenceState:
    """Logical-axes tree structurally matching an InferenceState.

    Params reuse the ParamFactory spec tree (same placement as training,
    minus the fsdp variant — serving has no optimizer state to amortize);
    cache leaves come from ``tfm.cache_axes`` whose ``cache_seq`` axis the
    rule table routes through ``cache_needs_seq_shard``."""
    return InferenceState(
        params=tfm.param_specs(cfg),
        cache=tfm.cache_axes(cfg),
        positions=("batch",),
        last_tok=("batch",),
    )


def new_inference_state(params: Any, cfg: ModelConfig, *, slots: int,
                        max_len: int, dtype=jnp.bfloat16) -> InferenceState:
    """Fresh state around ``params`` with ``slots`` empty request slots."""
    return InferenceState(
        params=params,
        cache=tfm.init_cache(cfg, slots, max_len, dtype=dtype),
        positions=jnp.zeros((slots,), jnp.int32),
        last_tok=jnp.zeros((slots,), jnp.int32),
    )


def scatter_slot(axes_tree: Any, full: Any, one: Any, slot) -> Any:
    """Write a single-request cache ``one`` (slot axis of size 1) into row
    ``slot`` of the slot-major cache ``full``.

    The slot axis is found per leaf from the logical-axes tree (scanned
    block leaves carry a leading layer-repetition axis before ``batch``),
    so one tree_map covers KV rings and recurrent state alike."""
    def _one(ax, f, o):
        i = ax.index("batch")
        idx = (slice(None),) * i + (slot,)
        return f.at[idx].set(jnp.take(o, 0, axis=i).astype(f.dtype))
    return jax.tree.map(_one, axes_tree, full, one, is_leaf=is_axes)
