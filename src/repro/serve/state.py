"""InferenceState: the single pytree the inference engine owns.

Mirror of ``repro.train.state``: everything a serving replica needs —
model parameters, the slot-major decode cache (KV rings for attention
layers, recurrent/conv state for RG-LRU and SSD layers) and the per-slot
position counters — travels through the jitted prefill-insert and decode
steps as one donated pytree, sharded by one structurally-matched
logical-spec tree resolved from the ``distributed/sharding.py`` rule
tables (the ``cache_seq`` axis takes the ``cache_needs_seq_shard``
branch so a long cache never replicates across the model axis).

The leading axis of every cache leaf is the REQUEST SLOT axis (logical
``batch`` -> the data/pod mesh axes): continuous batching allocates a
slot per admitted request and evicts it on EOS, so slots are recycled
in place with a scatter — the state never changes shape.

PAGED mode replaces the slot-major KV rings with a pool of fixed-size
pages plus a per-slot ``page_table`` (S, pages_per_slot): slot count is
decoupled from cache length, KV memory scales with live tokens instead
of ``slots * max_len``, and long prompts can be inserted chunk by chunk
into a slot's pages between fused decode steps.  Recurrent/SSM state
stays slot-major (it is O(1) per slot).  The contiguous layout remains
the parity baseline the serve tests pin paged mode against.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import is_axes
from repro.models import transformer as tfm


class InferenceState(NamedTuple):
    params: Any
    cache: Any            # tfm.init_cache / init_paged_cache pytree
    positions: jax.Array  # (S,) int32: next write index per slot
    last_tok: jax.Array   # (S,) int32: last accepted/emitted token per slot
    page_table: Any = None  # paged mode: (S, pages_per_slot) int32, -1 free
    # per-slot sampling config (serve/sampling.py): temperature <= 0 is the
    # greedy path; sample_key holds raw uint32 PRNG key data folded by
    # absolute stream position; tok_presence is the repetition-penalty
    # context mask over the (padded) vocab
    sample_temp: Any = None   # (S,) f32
    sample_top_k: Any = None  # (S,) int32, 0 = off
    sample_top_p: Any = None  # (S,) f32, 1.0 = off
    sample_rep: Any = None    # (S,) f32 repetition penalty, 1.0 = off
    sample_key: Any = None    # (S, 2) uint32 raw threefry key data
    tok_presence: Any = None  # (S, padded_vocab) bool


def _sampling_leaves(slots: int, vocab: int) -> dict:
    """Fresh (all-greedy) per-slot sampling arrays."""
    return dict(
        sample_temp=jnp.zeros((slots,), jnp.float32),
        sample_top_k=jnp.zeros((slots,), jnp.int32),
        sample_top_p=jnp.ones((slots,), jnp.float32),
        sample_rep=jnp.ones((slots,), jnp.float32),
        sample_key=jnp.zeros((slots, 2), jnp.uint32),
        tok_presence=jnp.zeros((slots, vocab), bool),
    )


_SAMPLING_AXES = dict(
    sample_temp=("batch",), sample_top_k=("batch",),
    sample_top_p=("batch",), sample_rep=("batch",),
    sample_key=("batch", None), tok_presence=("batch", None),
)


def inference_state_axes(cfg: ModelConfig) -> InferenceState:
    """Logical-axes tree structurally matching an InferenceState.

    Params reuse the ParamFactory spec tree (same placement as training,
    minus the fsdp variant — serving has no optimizer state to amortize);
    cache leaves come from ``tfm.cache_axes`` whose ``cache_seq`` axis the
    rule table routes through ``cache_needs_seq_shard``."""
    return InferenceState(
        params=tfm.param_specs(cfg),
        cache=tfm.cache_axes(cfg),
        positions=("batch",),
        last_tok=("batch",),
        **_SAMPLING_AXES,
    )


def new_inference_state(params: Any, cfg: ModelConfig, *, slots: int,
                        max_len: int, dtype=jnp.bfloat16) -> InferenceState:
    """Fresh state around ``params`` with ``slots`` empty request slots."""
    return InferenceState(
        params=params,
        cache=tfm.init_cache(cfg, slots, max_len, dtype=dtype),
        positions=jnp.zeros((slots,), jnp.int32),
        last_tok=jnp.zeros((slots,), jnp.int32),
        **_sampling_leaves(slots, cfg.padded_vocab()),
    )


def paged_inference_state_axes(cfg: ModelConfig) -> InferenceState:
    """Logical-axes tree for the paged layout: KV pools take the "pages" /
    "cache_seq" rules (the latter keeps the ``cache_needs_seq_shard``
    branch), the page table rides the slot ("batch") axis."""
    return InferenceState(
        params=tfm.param_specs(cfg),
        cache=tfm.paged_cache_axes(cfg),
        positions=("batch",),
        last_tok=("batch",),
        page_table=("batch", None),
        **_SAMPLING_AXES,
    )


def new_paged_inference_state(params: Any, cfg: ModelConfig, *, slots: int,
                              num_pages: int, pages_per_slot: int,
                              page_size: int,
                              dtype=jnp.bfloat16) -> InferenceState:
    """Fresh paged state: empty page pool, all page-table entries free."""
    return InferenceState(
        params=params,
        cache=tfm.init_paged_cache(cfg, slots, num_pages, page_size,
                                   dtype=dtype),
        positions=jnp.zeros((slots,), jnp.int32),
        last_tok=jnp.zeros((slots,), jnp.int32),
        page_table=jnp.full((slots, pages_per_slot), -1, jnp.int32),
        **_sampling_leaves(slots, cfg.padded_vocab()),
    )


def clear_pages(axes_tree: Any, cache: Any, pages: jax.Array,
                num_pages: int) -> Any:
    """Reset the position metadata of ``pages`` in every layer pool so a
    page recycled from an evicted request can never leak stale entries
    into its new owner's attention mask (positions are the only validity
    record — k/v bytes are inert once pos is -1)."""
    safe = jnp.where(pages >= 0, pages, num_pages)

    def _one(ax, leaf):
        if ax[-2:] != ("pages", "cache_seq"):
            return leaf
        i = ax.index("pages")
        idx = (slice(None),) * i + (safe,)
        return leaf.at[idx].set(-1, mode="drop")
    return jax.tree.map(_one, axes_tree, cache, is_leaf=is_axes)


def _flat_with_axes(axes_tree: Any, tree: Any):
    """Leaf-aligned (axes, leaves, treedef) triple: the axes tree is
    structurally identical to the cache tree, so flattening both (with
    ``is_axes`` stopping at spec tuples) yields parallel lists — the
    shape every page/slot row helper below works over."""
    ax = jax.tree.leaves(axes_tree, is_leaf=is_axes)
    leaves, treedef = jax.tree.flatten(tree)
    if len(ax) != len(leaves):
        raise ValueError(f"axes tree ({len(ax)} leaves) does not match "
                         f"cache tree ({len(leaves)} leaves)")
    return ax, leaves, treedef


def copy_pool_pages(axes_tree: Any, cache: Any, src: jax.Array,
                    dst: jax.Array) -> Any:
    """Copy-on-write: duplicate pool pages ``src`` into ``dst`` across
    every paged KV leaf — k, v AND pos, so the new owner's reads see the
    original pages' entries while its writes land in private copies.
    Recurrent/SSM leaves (slot-major, no "pages" axis) pass through."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)

    def _one(ax, leaf):
        if "pages" not in ax:
            return leaf
        i = ax.index("pages")
        rows = jnp.take(leaf, src, axis=i)
        idx = (slice(None),) * i + (dst,)
        return leaf.at[idx].set(rows)
    return jax.tree.map(_one, axes_tree, cache, is_leaf=is_axes)


def gather_page_rows(axes_tree: Any, cache: Any, pages) -> list:
    """Host (numpy) copies of the pool rows ``pages`` from every paged KV
    leaf, as a flat leaf-aligned list (``None`` for slot-major leaves) —
    the swap-out half of page-aware preemption: ``jax.device_get`` of
    just the victim's rows, never the whole pool."""
    ax, leaves, _ = _flat_with_axes(axes_tree, cache)
    idx = jnp.asarray(pages, jnp.int32)
    out = []
    for a, leaf in zip(ax, leaves):
        if "pages" not in a:
            out.append(None)
            continue
        i = a.index("pages")
        out.append(np.asarray(jax.device_get(jnp.take(leaf, idx, axis=i))))
    return out


def concat_page_rows(axes_tree: Any, blobs: list) -> list:
    """Merge per-page ``gather_page_rows`` blobs (leaf-aligned lists, one
    page each) into a single multi-page blob by concatenating along each
    leaf's pages axis — so a multi-page host-tier restore pays ONE
    ``scatter_page_rows`` device transfer instead of one per page.  The
    blobs must be leaf-aligned with ``axes_tree`` (``None`` on slot-major
    leaves, as ``gather_page_rows`` produces)."""
    if not blobs:
        raise ValueError("concat_page_rows needs at least one blob")
    ax = jax.tree.leaves(axes_tree, is_leaf=is_axes)
    out = []
    for li, a in enumerate(ax):
        parts = [b[li] for b in blobs]
        if parts[0] is None:
            out.append(None)
            continue
        out.append(np.concatenate(parts, axis=a.index("pages")))
    return out


def scatter_page_rows(axes_tree: Any, cache: Any, pages, rows: list) -> Any:
    """Write ``rows`` (a ``gather_page_rows`` blob) back into pool pages
    ``pages`` — the swap-in half.  The physical page ids may differ from
    the ones the blob was gathered at: page contents are keyed by absolute
    positions (the pos leaf travels in the blob), not by page id."""
    ax, leaves, treedef = _flat_with_axes(axes_tree, cache)
    idx = jnp.asarray(pages, jnp.int32)
    new = []
    for a, leaf, r in zip(ax, leaves, rows):
        if r is None:
            new.append(leaf)
            continue
        i = a.index("pages")
        sel = (slice(None),) * i + (idx,)
        new.append(leaf.at[sel].set(jnp.asarray(r, leaf.dtype)))
    return jax.tree.unflatten(treedef, new)


def gather_slot_rows(axes_tree: Any, cache: Any, slot: int) -> list:
    """Host copies of row ``slot`` from every slot-major (recurrent/SSM)
    cache leaf, leaf-aligned list with ``None`` for paged KV leaves.
    Pages hold only attention KV, so this is the rest of a slot's resume
    state: prefix-cache snapshots at page boundaries and the recurrent
    half of a preemption swap blob."""
    ax, leaves, _ = _flat_with_axes(axes_tree, cache)
    out = []
    for a, leaf in zip(ax, leaves):
        if "batch" not in a:
            out.append(None)
            continue
        i = a.index("batch")
        out.append(np.asarray(jax.device_get(jnp.take(leaf, slot, axis=i))))
    return out


def scatter_slot_rows(axes_tree: Any, cache: Any, slot: int,
                      rows: list) -> Any:
    """Write a ``gather_slot_rows`` blob into row ``slot`` (any slot — the
    restore target need not be the slot the blob was gathered from)."""
    ax, leaves, treedef = _flat_with_axes(axes_tree, cache)
    new = []
    for a, leaf, r in zip(ax, leaves, rows):
        if r is None:
            new.append(leaf)
            continue
        i = a.index("batch")
        idx = (slice(None),) * i + (slot,)
        new.append(leaf.at[idx].set(jnp.asarray(r, leaf.dtype)))
    return jax.tree.unflatten(treedef, new)


def select_verified(axes_tree: Any, stacked: Any, old: Any, n: jax.Array,
                    active: jax.Array) -> Any:
    """Roll the cache back to each slot's last accepted token after a
    speculative verify step.

    ``stacked`` is the cache tree ``tfm.verify_step_paged`` returned:
    attention page pools are final (rejected writes are shadowed by the
    positional mask — nothing to undo), while recurrent/SSM leaves carry a
    per-step snapshot axis inserted just before their slot ("batch") axis.
    ``n`` (S,) is the number of accepted draft tokens per slot: snapshot
    index ``n[s]`` is the state after consuming the last accepted token.
    Inactive slots keep their rows from ``old`` untouched."""
    def _one(ax, st, o):
        if "batch" not in ax:
            return st               # paged KV pool: positional shadowing
        i = ax.index("batch")       # step axis sits at i, slots at i+1
        idx = n.reshape((1,) * (i + 1) + (-1,) + (1,) * (st.ndim - i - 2))
        sel = jnp.squeeze(jnp.take_along_axis(st, idx, axis=i), axis=i)
        m = active.reshape((1,) * i + (-1,) + (1,) * (sel.ndim - i - 1))
        return jnp.where(m, sel.astype(o.dtype), o)
    return jax.tree.map(_one, axes_tree, stacked, old, is_leaf=is_axes)


def scatter_slot(axes_tree: Any, full: Any, one: Any, slot) -> Any:
    """Write a single-request cache ``one`` (slot axis of size 1) into row
    ``slot`` of the slot-major cache ``full``.

    The slot axis is found per leaf from the logical-axes tree (scanned
    block leaves carry a leading layer-repetition axis before ``batch``),
    so one tree_map covers KV rings and recurrent state alike."""
    def _one(ax, f, o):
        i = ax.index("batch")
        idx = (slice(None),) * i + (slot,)
        return f.at[idx].set(jnp.take(o, 0, axis=i).astype(f.dtype))
    return jax.tree.map(_one, axes_tree, full, one, is_leaf=is_axes)
