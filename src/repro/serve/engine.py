"""The mesh-sharded inference engine — serving twin of ``train.Engine``.

One :class:`InferenceEngine` owns what the standalone decode loop in the
old ``launch/serve.py`` hand-rolled (unsharded, random params, no slot
reuse):

  * the logical-axis rule tables from ``distributed/sharding.py`` resolved
    into ``in_shardings``/``out_shardings`` for the whole
    :class:`InferenceState` — params under the same placement training
    used, cache leaves through ``cache_axes`` where the ``cache_seq`` rule
    takes the ``cache_needs_seq_shard`` branch;
  * a jitted, donated prefill-insert step: prefill ONE request at its
    exact prompt length (no padding, so recurrent/SSM state is exact) and
    scatter its cache into a free slot of the slot-major state;
  * a jitted, donated decode step over ALL slots at once, each advancing
    its own position counter (ragged prompt lengths coexist in one batch);
  * optionally a PAGED cache (``paged=True``): a fixed-size page pool +
    per-slot page tables decouple slot count from ``max_len`` (KV memory
    follows live tokens), and ``prefill_chunk`` admits long prompts chunk
    by chunk through ``insert_chunk`` so the scheduler can interleave
    admission with decode — the contiguous layout stays available as the
    parity baseline;
  * the trained-checkpoint hand-off: ``from_train_state`` adopts a live
    ``TrainState.params`` without gathering to host, and
    ``restore_params`` rebuilds only the params subtree of a TrainState
    .npz (optimizer moments are never instantiated).

Slot allocation / EOS eviction policy lives in ``serve.scheduler``; the
engine is policy-free and model-agnostic across every
``cfg.supports_decode()`` architecture.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

import numpy as np

from repro import checkpoint as ckpt
from repro.configs.base import ModelConfig
from repro.obs import profiler
from repro.distributed.sharding import (
    logical_sharding, make_rules, resolve_pspec, tree_shardings,
)
from repro.models import transformer as tfm
from repro.serve import sampling
from repro.serve.state import (
    InferenceState, clear_pages, concat_page_rows, copy_pool_pages,
    gather_page_rows, gather_slot_rows, inference_state_axes, is_axes,
    new_inference_state, new_paged_inference_state,
    paged_inference_state_axes, scatter_page_rows, scatter_slot,
    scatter_slot_rows, select_verified,
)


class InferenceEngine:
    """Sharded, donated prefill/decode step factory over request slots.

    ``paged=True`` swaps the slot-major KV rings for a page pool + per-slot
    page tables (slot count decoupled from ``max_len``; ``num_pages`` sizes
    KV memory to live tokens) and unlocks ``prefill_chunk``: long prompts
    are inserted ``prefill_chunk`` tokens at a time via :meth:`insert_chunk`
    so the scheduler can interleave admission with fused decode steps."""

    def __init__(self, cfg: ModelConfig, *, mesh=None, slots: int = 4,
                 max_len: int = 64, dtype=jnp.bfloat16,
                 rules: Optional[dict] = None, donate: bool = True,
                 explicit_shardings: bool = True, paged: bool = False,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 prefill_chunk: int = 0):
        if not cfg.supports_decode():
            raise ValueError(f"{cfg.name} has no decode path")
        self.cfg = cfg
        self.slots = int(slots)
        self.max_len = int(max_len)
        self.dtype = dtype
        self.donate = donate
        self.paged = bool(paged)
        self.prefill_chunk = int(prefill_chunk)
        if self.prefill_chunk and not self.paged:
            raise ValueError("prefill_chunk requires the paged cache "
                             "(chunks are written into page tables)")
        # mesh and rules are built LAZILY, mirroring train.Engine: never
        # touch jax device state before the launcher injects XLA_FLAGS
        self._mesh = mesh
        self._rules = rules
        self._explicit = explicit_shardings
        if self.paged:
            if page_size < 1:
                raise ValueError(f"page_size must be >= 1, got {page_size}")
            self.page_size = int(page_size)
            self.pages_per_slot = -(-self.max_len // self.page_size)
            self.num_pages = int(num_pages) if num_pages \
                else self.slots * self.pages_per_slot
            self._axes = paged_inference_state_axes(cfg)
            self._cache_axes = tfm.paged_cache_axes(cfg)
        else:
            self.page_size = self.pages_per_slot = self.num_pages = None
            self._axes = inference_state_axes(cfg)
            self._cache_axes = tfm.cache_axes(cfg)
        self._jit_cache: dict = {}
        self._state_shardings = None
        self._has_rec: Optional[bool] = None

    @property
    def mesh(self):
        if self._mesh is None:
            from repro.launch.mesh import make_host_mesh
            self._mesh = make_host_mesh()
        return self._mesh

    @property
    def rules(self) -> dict:
        if self._rules is None:
            self._rules = make_rules(self.cfg, mesh=self.mesh)
        return self._rules

    # -- state lifecycle ---------------------------------------------------
    def init_state(self, params: Any) -> InferenceState:
        """Fresh InferenceState around ``params``, placed on its shardings.

        Takes OWNERSHIP of ``params`` (the buffers are donated through the
        jitted steps): when handing off a live TrainState the training side
        must be done with it, and when the shardings already match — the
        ``from_train_state`` path — the device_put is a no-op and the
        weights never return to host."""
        if self.paged:
            state = new_paged_inference_state(
                params, self.cfg, slots=self.slots, num_pages=self.num_pages,
                pages_per_slot=self.pages_per_slot, page_size=self.page_size,
                dtype=self.dtype)
        else:
            state = new_inference_state(params, self.cfg, slots=self.slots,
                                        max_len=self.max_len,
                                        dtype=self.dtype)
        if self._explicit:
            state = jax.device_put(state, self.state_shardings(state))
        return state

    def assign_pages(self, state: InferenceState, slot: int, pages,
                     fresh=None) -> InferenceState:
        """Install ``pages`` (an ordered list of physical page ids from the
        scheduler's free list) as ``slot``'s page row, and reset the
        position metadata of the FRESH ones in every layer pool — a page
        recycled from an evicted request must never leak stale entries
        into its new owner's attention mask.  ``fresh`` defaults to all of
        ``pages``; a prefix-cache admission passes only its newly-claimed
        pages so the shared run's cached entries survive the install.
        Host-side policy hook, outside the jitted steps."""
        assert self.paged, "assign_pages is a paged-mode operation"
        row = np.full((self.pages_per_slot,), -1, np.int32)
        row[:len(pages)] = pages
        table = state.page_table.at[slot].set(jnp.asarray(row))
        clear = list(pages) if fresh is None else list(fresh)
        cache = state.cache
        if clear:
            cache = clear_pages(self._cache_axes, cache,
                                jnp.asarray(clear, jnp.int32),
                                self.num_pages)
        if self._explicit:
            # re-place only what this host-side update touched — the params
            # subtree (hundreds of leaves) is untouched and stays put
            sh = self.state_shardings(state)
            cache = jax.device_put(cache, sh.cache)
            table = jax.device_put(table, sh.page_table)
        return state._replace(cache=cache, page_table=table)

    @property
    def has_recurrent_state(self) -> bool:
        """True when the arch keeps slot-major recurrent/SSM cache leaves
        alongside the paged KV pools.  Pages hold only attention KV, so a
        prefix-cache hit on such an arch must restore the recurrent state
        at the resume offset from a host-side snapshot (the radix cache
        stores one per registered page boundary)."""
        if self._has_rec is None:
            axes = jax.tree.leaves(self._cache_axes, is_leaf=is_axes)
            self._has_rec = any("batch" in a for a in axes) if self.paged \
                else True
        return self._has_rec

    def copy_pages(self, state: InferenceState, src, dst) -> InferenceState:
        """Copy-on-write: clone pool pages ``src`` into ``dst`` across
        every paged KV leaf (k, v and pos).  The scheduler calls this when
        an admission must write into a page whose refcount it does not
        exclusively own — the write lands in the private ``dst`` copy and
        the shared original stays immutable for its other readers."""
        assert self.paged, "copy_pages is a paged-mode operation"
        cache = copy_pool_pages(self._cache_axes, state.cache, src, dst)
        if self._explicit:
            cache = jax.device_put(cache, self.state_shardings(state).cache)
        return state._replace(cache=cache)

    def get_slot_state(self, state: InferenceState, slot: int) -> list:
        """Host snapshot of ``slot``'s recurrent/SSM rows (leaf-aligned,
        ``None`` per paged KV leaf) — what the prefix cache stores per
        registered page boundary so a later hit can resume mid-prompt."""
        assert self.paged, "get_slot_state is a paged-mode operation"
        return gather_slot_rows(self._cache_axes, state.cache, int(slot))

    def set_slot_state(self, state: InferenceState, slot: int,
                       rows: list) -> InferenceState:
        """Restore a ``get_slot_state`` snapshot into ``slot`` (any slot)."""
        assert self.paged, "set_slot_state is a paged-mode operation"
        cache = scatter_slot_rows(self._cache_axes, state.cache, int(slot),
                                  rows)
        if self._explicit:
            cache = jax.device_put(cache, self.state_shardings(state).cache)
        return state._replace(cache=cache)

    def _install_sampling(self, state: InferenceState, slot: int,
                          temp: float, top_k: int, top_p: float, rep: float,
                          key, presence) -> InferenceState:
        """Write one slot's sampling rows (host-side policy hook shared by
        ``set_sampling`` and ``swap_in``), re-placing only what changed."""
        state = state._replace(
            sample_temp=state.sample_temp.at[slot].set(float(temp)),
            sample_top_k=state.sample_top_k.at[slot].set(int(top_k)),
            sample_top_p=state.sample_top_p.at[slot].set(float(top_p)),
            sample_rep=state.sample_rep.at[slot].set(float(rep)),
            sample_key=state.sample_key.at[slot].set(
                jnp.asarray(key, jnp.uint32)),
            tok_presence=state.tok_presence.at[slot].set(
                jnp.asarray(presence, bool)),
        )
        if self._explicit:
            sh = self.state_shardings(state)
            state = state._replace(
                sample_temp=jax.device_put(state.sample_temp,
                                           sh.sample_temp),
                sample_top_k=jax.device_put(state.sample_top_k,
                                            sh.sample_top_k),
                sample_top_p=jax.device_put(state.sample_top_p,
                                            sh.sample_top_p),
                sample_rep=jax.device_put(state.sample_rep, sh.sample_rep),
                sample_key=jax.device_put(state.sample_key, sh.sample_key),
                tok_presence=jax.device_put(state.tok_presence,
                                            sh.tok_presence),
            )
        return state

    def set_sampling(self, state: InferenceState, slot: int,
                     params: "sampling.SamplingParams",
                     context=()) -> InferenceState:
        """Install a request's :class:`~repro.serve.sampling.SamplingParams`
        into ``slot``'s per-slot arrays at admission: parameters, the
        seed-derived base PRNG key, and the repetition-penalty presence
        row seeded with ``context`` (the full prompt — also on a
        prefix-cache resume, so the mask never depends on the resume
        offset).  Host-side policy hook, outside the jitted steps."""
        params.validate()
        return self._install_sampling(
            state, int(slot), params.temperature, params.top_k,
            params.top_p, params.rep_penalty,
            sampling.base_key(params.seed),
            sampling.presence_row(context, self.cfg.padded_vocab()))

    def swap_out(self, state: InferenceState, slot: int, pages) -> dict:
        """Page-aware preemption, out half: ``jax.device_get`` of JUST the
        victim's pool rows (every paged KV leaf at ``pages``) plus its
        slot-major recurrent rows and counters.  Together with the host-
        side request (prompt + generated tokens) the blob is the complete
        resume state; the pages and the slot can be handed to another
        request immediately."""
        assert self.paged, "swap_out is a paged-mode operation"
        slot = int(slot)
        return {
            "kv": gather_page_rows(self._cache_axes, state.cache, pages),
            "rec": gather_slot_rows(self._cache_axes, state.cache, slot),
            "pos": int(jax.device_get(state.positions[slot])),
            "last_tok": int(jax.device_get(state.last_tok[slot])),
            # sampling travels in the blob so a restored request keeps
            # drawing the exact stream it was preempted from (the base
            # key plus the restored position counter reproduce the folds)
            "samp": {
                "temp": float(jax.device_get(state.sample_temp[slot])),
                "top_k": int(jax.device_get(state.sample_top_k[slot])),
                "top_p": float(jax.device_get(state.sample_top_p[slot])),
                "rep": float(jax.device_get(state.sample_rep[slot])),
                "key": np.asarray(jax.device_get(state.sample_key[slot])),
                "presence": np.asarray(
                    jax.device_get(state.tok_presence[slot])),
            },
        }

    def swap_in(self, state: InferenceState, slot: int, pages,
                blob: dict) -> InferenceState:
        """Restore a ``swap_out`` blob into ``slot`` over freshly-claimed
        ``pages`` (same count and order as the swap-out run; the physical
        ids may differ — page contents are keyed by absolute position).
        The victim resumes decoding exactly where it was preempted."""
        assert self.paged, "swap_in is a paged-mode operation"
        with profiler.annotate("serve.swap_in"):
            return self._swap_in(state, slot, pages, blob)

    def _swap_in(self, state: InferenceState, slot: int, pages,
                 blob: dict) -> InferenceState:
        state = self.assign_pages(state, slot, pages)
        cache = scatter_page_rows(self._cache_axes, state.cache, pages,
                                  blob["kv"])
        cache = scatter_slot_rows(self._cache_axes, cache, int(slot),
                                  blob["rec"])
        positions = state.positions.at[slot].set(blob["pos"])
        last_tok = state.last_tok.at[slot].set(blob["last_tok"])
        if self._explicit:
            sh = self.state_shardings(state)
            cache = jax.device_put(cache, sh.cache)
            positions = jax.device_put(positions, sh.positions)
            last_tok = jax.device_put(last_tok, sh.last_tok)
        state = state._replace(cache=cache, positions=positions,
                               last_tok=last_tok)
        samp = blob["samp"]
        return self._install_sampling(
            state, int(slot), samp["temp"], samp["top_k"], samp["top_p"],
            samp["rep"], samp["key"], samp["presence"])

    def spill_page(self, state: InferenceState, page: int) -> list:
        """Host copy of ONE pool page across every paged KV leaf — what
        the radix cache's host tier stores when ``_reclaim`` evicts a
        cached (ref-0) page under pool pressure.  Leaf-aligned like
        ``gather_page_rows`` (``None`` on slot-major leaves); the pos
        leaf travels in the blob, so the content stays keyed by absolute
        stream positions, never by the physical page id."""
        assert self.paged, "spill_page is a paged-mode operation"
        return gather_page_rows(self._cache_axes, state.cache, [int(page)])

    def restore_pages(self, state: InferenceState, pages,
                      blobs: list) -> InferenceState:
        """Scatter per-page spill blobs (one :meth:`spill_page` blob per
        entry of ``pages``, in order) back into freshly-claimed pool
        pages — the restore half of a host-tier prefix hit: the KV those
        pages held returns by a host-to-device copy instead of prefill
        compute.  The physical ids may differ from the spill-time ones;
        page contents are keyed by the absolute positions in the pos
        leaf, exactly like a preemption ``swap_in``."""
        assert self.paged, "restore_pages is a paged-mode operation"
        with profiler.annotate("serve.restore_pages"):
            rows = concat_page_rows(self._cache_axes, blobs)
            cache = scatter_page_rows(self._cache_axes, state.cache, pages,
                                      rows)
            if self._explicit:
                cache = jax.device_put(cache,
                                       self.state_shardings(state).cache)
            return state._replace(cache=cache)

    def release_pages(self, state: InferenceState,
                      slot: int) -> InferenceState:
        """Clear ``slot``'s page row on eviction.  The freed pages may be
        handed to another request immediately, and a cleared row (-1)
        turns any later write through this slot — e.g. a mask-free
        ``decode(state)`` — into a dropped out-of-bounds scatter instead
        of a silent write into the new owner's pages."""
        assert self.paged, "release_pages is a paged-mode operation"
        return state._replace(page_table=state.page_table.at[slot].set(-1))

    @classmethod
    def from_train_state(cls, train_engine, train_state, *, slots: int = 4,
                         max_len: int = 64, dtype=jnp.bfloat16,
                         **kw) -> tuple["InferenceEngine", InferenceState]:
        """Adopt a trained ``TrainState`` from a ``train.Engine`` in place.

        The inference engine reuses the train engine's mesh; its rule table
        resolves the params to the same NamedShardings training used (the
        fsdp variant re-gathers shard-to-shard on device), so the returned
        InferenceState is built without a host round-trip.  The train state
        must not be stepped afterwards — its params are donated here."""
        eng = cls(train_engine.cfg, mesh=train_engine.mesh, slots=slots,
                  max_len=max_len, dtype=dtype, **kw)
        return eng, eng.init_state(train_state.params)

    def restore_params(self, path: str, example_params: Any) -> Any:
        """Params subtree of a full-TrainState .npz, restored into
        ``example_params`` — the CLI hand-off (``--ckpt`` from
        ``repro.launch.train``) without touching optimizer moments."""
        return ckpt.restore_subtree(path, example_params, prefix="params")

    # -- sharding resolution -----------------------------------------------
    def state_shardings(self, state: InferenceState) -> InferenceState:
        """NamedSharding tree matching ``state`` from the rule tables.
        Cached after the first resolution — the engine's state shapes are
        fixed, and admissions (``assign_pages``) re-place the state on
        every request."""
        if self._state_shardings is None:
            self._state_shardings = tree_shardings(self._axes, state,
                                                   self.mesh, self.rules)
        return self._state_shardings

    def _input_shardings(self, inputs: Dict[str, jax.Array]):
        out = {}
        for k, v in inputs.items():
            axes = ("batch",) + (None,) * (jnp.ndim(v) - 1)
            out[k] = NamedSharding(self.mesh, resolve_pspec(
                axes, jnp.shape(v), self.mesh, self.rules))
        return out

    # -- the steps ---------------------------------------------------------
    def _sample_args(self, state: InferenceState) -> dict:
        return dict(keys=state.sample_key, temperature=state.sample_temp,
                    top_k=state.sample_top_k, top_p=state.sample_top_p,
                    rep_penalty=state.sample_rep)

    def _sample_one(self, state: InferenceState, logits: jax.Array,
                    slot: jax.Array, pos) -> jax.Array:
        """First-token emission for one slot (prefill / final chunk):
        argmax when the slot is greedy, else a draw at absolute stream
        position ``pos`` under the slot's own parameters.  No presence
        fold — the prompt's presence was installed host-side at admission
        (``set_sampling``) and the emitted token folds in at the step
        that consumes it."""
        greedy = jnp.argmax(logits, -1).astype(jnp.int32)       # (1,)

        def _go():
            toks = sampling.draw(
                logits, keys=state.sample_key[slot][None],
                positions=jnp.asarray(pos, jnp.int32)[None],
                temperature=state.sample_temp[slot][None],
                top_k=state.sample_top_k[slot][None],
                top_p=state.sample_top_p[slot][None],
                rep_penalty=state.sample_rep[slot][None],
                presence=state.tok_presence[slot][None])
            return toks
        return jax.lax.cond(state.sample_temp[slot] > 0, _go,
                            lambda: greedy)

    def _sample_all(self, state: InferenceState, logits: jax.Array,
                    positions: jax.Array, active=None):
        """All-slot emission for the fused decode: (tokens (S,), presence).
        The single ``lax.cond`` keeps an all-greedy batch bit-identical
        to (and as cheap as) the bare argmax path; otherwise every slot
        first folds the input token it just consumed (``last_tok``) into
        its presence row, then draws with its position-folded key —
        greedy slots still take the raw argmax."""
        greedy = jnp.argmax(logits, -1).astype(jnp.int32)       # (S,)

        def _go():
            S = logits.shape[0]
            pres = state.tok_presence.at[
                jnp.arange(S), state.last_tok].set(True)
            if active is not None:
                pres = jnp.where(active[:, None], pres, state.tok_presence)
            toks = sampling.draw(logits, positions=positions, presence=pres,
                                 **self._sample_args(state))
            return jnp.where(state.sample_temp > 0, toks, greedy), pres
        return jax.lax.cond(jnp.any(state.sample_temp > 0), _go,
                            lambda: (greedy, state.tok_presence))

    def _insert_fn(self, state: InferenceState, inputs: Dict[str, jax.Array],
                   slot: jax.Array):
        logits, cache_one = tfm.prefill(state.params, self.cfg, inputs,
                                        max_len=self.max_len,
                                        dtype=self.dtype)
        total = inputs["tokens"].shape[1] + (
            inputs["patches"].shape[1] if "patches" in inputs else 0)
        tok = self._sample_one(state, logits, slot, total)      # (1,)
        if self.paged:
            # same exact-length prefill; the ring cache scatters into the
            # slot's pages instead of a slot row
            cache = tfm.scatter_prefill_paged(
                self.cfg, state.cache, cache_one, state.page_table[slot],
                slot)
        else:
            cache = scatter_slot(self._cache_axes, state.cache, cache_one,
                                 slot)
        return state._replace(
            cache=cache,
            positions=state.positions.at[slot].set(total),
            last_tok=state.last_tok.at[slot].set(tok[0]),
        ), tok

    def _chunk_fn(self, state: InferenceState, inputs: Dict[str, jax.Array],
                  slot: jax.Array, pos_start: jax.Array):
        logits, cache = tfm.prefill_chunk(
            state.params, self.cfg, inputs, state.cache,
            state.page_table[slot], slot, pos_start, dtype=self.dtype)
        end = pos_start + inputs["tokens"].shape[1]
        # only the final chunk's token is kept, and ``end`` is then the
        # same absolute position a whole-prompt insert would fold — the
        # draw is invariant under chunking
        tok = self._sample_one(state, logits, slot, end)        # (1,)
        return state._replace(
            cache=cache,
            positions=state.positions.at[slot].set(end),
            last_tok=state.last_tok.at[slot].set(tok[0]),
        ), tok

    def _decode_fn(self, state: InferenceState):
        logits, cache = tfm.decode_step(
            state.params, self.cfg, {"tokens": state.last_tok[:, None]},
            state.cache, state.positions, dtype=self.dtype)
        tok, presence = self._sample_all(state, logits,
                                         state.positions + 1)  # (slots,)
        return state._replace(cache=cache, positions=state.positions + 1,
                              last_tok=tok, tok_presence=presence), tok

    def _decode_paged_fn(self, state: InferenceState, active: jax.Array):
        logits, cache = tfm.decode_step_paged(
            state.params, self.cfg, {"tokens": state.last_tok[:, None]},
            state.cache, state.positions, state.page_table, active,
            dtype=self.dtype)
        tok, presence = self._sample_all(state, logits,
                                         state.positions + 1, active)
        return state._replace(
            cache=cache,
            positions=state.positions + active.astype(jnp.int32),
            last_tok=jnp.where(active, tok, state.last_tok),
            tok_presence=presence,
        ), tok

    def _verify_fn(self, state: InferenceState, drafts: jax.Array,
                   draft_len: jax.Array, active: jax.Array):
        """One fused speculative step: feed each active slot its last token
        plus ``drafts`` (S, K) proposed tokens, verify in ONE paged forward,
        and accept the longest prefix of drafts matching the model's OWN
        next tokens — the raw argmax for greedy slots, a position-keyed
        draw from the (penalized/filtered) target distribution for
        sampled slots.

        Losslessness: for a greedy slot this is the classic greedy
        prefix-match.  For a sampled slot it is rejection-sampling
        verification specialized to a DETERMINISTIC drafter (the draft
        distribution is a point mass, so ``min(1, p/q)`` acceptance +
        residual resampling collapses to: draw ``t_i`` from the target at
        position ``i`` with that position's folded key, accept the draft
        iff it equals ``t_i``, and emit ``t_i`` either way) — the emitted
        stream is therefore BIT-IDENTICAL to the non-speculative sampled
        stream at equal seeds, not merely equal in distribution.  Rejected
        KV writes are shadowed by the positional mask, and recurrent/SSM
        state rolls back to the per-step snapshot at the last accepted
        token."""
        S, K = drafts.shape
        toks = jnp.concatenate([state.last_tok[:, None], drafts], axis=1)
        logits, stacked = tfm.verify_step_paged(
            state.params, self.cfg, {"tokens": toks}, state.cache,
            state.positions, state.page_table, active, dtype=self.dtype)
        greedy = jnp.argmax(logits, -1).astype(jnp.int32)       # (S, K+1)
        any_sampled = jnp.any(state.sample_temp > 0)
        ar_s = jnp.arange(S)

        def _sampled_targets():
            # walk the K+1 positions in order, folding each INPUT token
            # into presence before drawing its successor — the same
            # presence/position alignment K+1 successive decode steps
            # would produce, which is what makes spec == non-spec exact
            pres = state.tok_presence
            cols = []
            for i in range(K + 1):
                pres = pres.at[ar_s, toks[:, i]].set(True)
                t = sampling.draw(logits[:, i],
                                  positions=state.positions + i + 1,
                                  presence=pres,
                                  **self._sample_args(state))
                cols.append(jnp.where(state.sample_temp > 0, t,
                                      greedy[:, i]))
            return jnp.stack(cols, axis=1)
        target = jax.lax.cond(any_sampled, _sampled_targets,
                              lambda: greedy)                   # (S, K+1)
        ar = jnp.arange(K, dtype=jnp.int32)[None, :]
        match = (target[:, :-1] == drafts) & (ar < draft_len[:, None])
        # accepted drafts = longest matching prefix; emitted = accepted + 1
        # (the model's own next token after the last accepted position)
        n = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
        consumed = jnp.where(active, n + 1, 0).astype(jnp.int32)
        cache = select_verified(self._cache_axes, stacked, state.cache, n,
                                active)
        last = jnp.take_along_axis(target, n[:, None], axis=1)[:, 0]

        def _commit_presence():
            # fold exactly the inputs this step consumed (j < consumed):
            # the rejected tail must not poison the repetition mask
            pres = state.tok_presence
            for j in range(K + 1):
                upd = pres.at[ar_s, toks[:, j]].set(True)
                pres = jnp.where(((j < consumed) & active)[:, None],
                                 upd, pres)
            return pres
        presence = jax.lax.cond(any_sampled, _commit_presence,
                                lambda: state.tok_presence)
        return state._replace(
            cache=cache,
            positions=state.positions + consumed,
            last_tok=jnp.where(active, last, state.last_tok),
            tok_presence=presence,
        ), target, consumed

    def _active_sharding(self):
        return NamedSharding(self.mesh, resolve_pspec(
            ("batch",), (self.slots,), self.mesh, self.rules))

    def _get_jit(self, kind: str, state, inputs=None):
        key = (kind,) + (tuple(sorted(
            (k, tuple(jnp.shape(v)), str(v.dtype))
            for k, v in inputs.items())) if inputs else ())
        jfn = self._jit_cache.get(key)
        if jfn is None:
            fns = {"insert": self._insert_fn, "chunk": self._chunk_fn,
                   "decode": self._decode_fn,
                   "decode_paged": self._decode_paged_fn,
                   "verify": self._verify_fn}
            fn = fns[kind]
            donate = (0,) if self.donate else ()
            if not self._explicit:
                jfn = jax.jit(fn, donate_argnums=donate)
            else:
                st_sh = self.state_shardings(state)
                if kind == "insert":
                    in_sh = (st_sh, self._input_shardings(inputs), None)
                elif kind == "chunk":
                    in_sh = (st_sh, self._input_shardings(inputs), None, None)
                elif kind == "decode":
                    in_sh = (st_sh,)
                elif kind == "verify":
                    in_sh = (st_sh, self._input_shardings(inputs)["drafts"],
                             self._active_sharding(),
                             self._active_sharding())
                else:
                    in_sh = (st_sh, self._active_sharding())
                out_sh = (st_sh, None, None) if kind == "verify" \
                    else (st_sh, None)
                jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                              donate_argnums=donate)
            self._jit_cache[key] = jfn
        return jfn

    def _run(self, jfn, *args):
        if not self._explicit:
            return jfn(*args)
        with self.mesh, logical_sharding(self.mesh, self.rules):
            return jfn(*args)

    def insert(self, state: InferenceState, inputs: Dict[str, jax.Array],
               slot: int):
        """Prefill ONE request (tokens (1, L), exact length — plus patches
        for VLM archs) into slot ``slot``.  Returns (state, first greedy
        token (1,)).  Jit-cached per distinct prompt shape.  In paged mode
        the slot's page row must already be installed (``assign_pages``)."""
        inputs = {k: jnp.asarray(v) for k, v in inputs.items()}
        jfn = self._get_jit("insert", state, inputs)
        # annotate() scopes are live only inside an open jax.profiler
        # window (--profile-dir): host phase names then line up with the
        # device timeline; otherwise they are null contexts
        with profiler.annotate("serve.insert"):
            return self._run(jfn, state, inputs,
                             jnp.asarray(slot, jnp.int32))

    def insert_chunk(self, state: InferenceState,
                     inputs: Dict[str, jax.Array], slot: int,
                     pos_start: int):
        """Insert ONE prompt chunk (tokens (1, C)) starting at absolute
        position ``pos_start`` into slot ``slot``'s pages.  Returns
        (state, greedy token (1,)) — the token is meaningful only for the
        final chunk of a prompt.  Jit-cached per chunk shape, so a prompt
        split into fixed-size chunks compiles twice at most (body +
        remainder)."""
        assert self.paged, "insert_chunk requires the paged cache"
        inputs = {k: jnp.asarray(v) for k, v in inputs.items()}
        jfn = self._get_jit("chunk", state, inputs)
        with profiler.annotate("serve.insert_chunk"):
            return self._run(jfn, state, inputs,
                             jnp.asarray(slot, jnp.int32),
                             jnp.asarray(pos_start, jnp.int32))

    def verify(self, state: InferenceState, drafts, draft_len, active):
        """One fused speculative decode step over ALL slots.  ``drafts``
        (slots, K) int32 proposed tokens per slot (row ``s`` meaningful up
        to ``draft_len[s]``; the rest is padding whose cache writes are
        shadowed exactly like rejected drafts); ``active`` (slots,) bool as
        in :meth:`decode`.  Returns (state, emitted (slots, K+1) target
        tokens, consumed (slots,)): slot ``s`` emitted
        ``emitted[s, :consumed[s]]`` — its own continuation under its
        sampling params (argmax for greedy slots), bit-identical to
        ``consumed[s]`` successive :meth:`decode` calls — and advanced
        its position by ``consumed[s]``.  Jit-cached per K."""
        if not self.paged:
            raise ValueError("speculative verification writes draft KV "
                             "through page tables; build the engine with "
                             "paged=True (the --spec-k 0 contiguous path "
                             "is the parity baseline)")
        drafts = jnp.asarray(drafts, jnp.int32)
        jfn = self._get_jit("verify", state, {"drafts": drafts})
        with profiler.annotate("serve.verify"):
            return self._run(jfn, state, drafts,
                             jnp.asarray(draft_len, jnp.int32),
                             jnp.asarray(active, bool))

    def decode(self, state: InferenceState, active=None):
        """One decode step over ALL slots: each slot's last token advances
        its own position counter.  Returns (state, greedy tokens (slots,));
        free slots produce garbage tokens the scheduler ignores.

        In paged mode ``active`` (slots,) bool gates all writes: inactive
        slots neither touch the page pool nor advance their counters.
        Mask-free calls are safe against evicted slots (``release_pages``
        clears their page rows, turning stray writes into dropped
        scatters), but the mask is REQUIRED while any slot is
        mid-chunked-prefill — only the caller knows those slots, and an
        unmasked decode would advance their recurrent state."""
        if self.paged:
            if active is None:
                active = np.ones((self.slots,), bool)
            jfn = self._get_jit("decode_paged", state)
            with profiler.annotate("serve.decode"):
                return self._run(jfn, state, jnp.asarray(active, bool))
        if active is not None:
            raise ValueError("active masks are a paged-mode feature; the "
                             "contiguous decode advances every slot")
        jfn = self._get_jit("decode", state)
        with profiler.annotate("serve.decode"):
            return self._run(jfn, state)
