"""The mesh-sharded inference engine — serving twin of ``train.Engine``.

One :class:`InferenceEngine` owns what the standalone decode loop in the
old ``launch/serve.py`` hand-rolled (unsharded, random params, no slot
reuse):

  * the logical-axis rule tables from ``distributed/sharding.py`` resolved
    into ``in_shardings``/``out_shardings`` for the whole
    :class:`InferenceState` — params under the same placement training
    used, cache leaves through ``cache_axes`` where the ``cache_seq`` rule
    takes the ``cache_needs_seq_shard`` branch;
  * a jitted, donated prefill-insert step: prefill ONE request at its
    exact prompt length (no padding, so recurrent/SSM state is exact) and
    scatter its cache into a free slot of the slot-major state;
  * a jitted, donated decode step over ALL slots at once, each advancing
    its own position counter (ragged prompt lengths coexist in one batch);
  * the trained-checkpoint hand-off: ``from_train_state`` adopts a live
    ``TrainState.params`` without gathering to host, and
    ``restore_params`` rebuilds only the params subtree of a TrainState
    .npz (optimizer moments are never instantiated).

Slot allocation / EOS eviction policy lives in ``serve.scheduler``; the
engine is policy-free and model-agnostic across every
``cfg.supports_decode()`` architecture.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro import checkpoint as ckpt
from repro.configs.base import ModelConfig
from repro.distributed.sharding import (
    logical_sharding, make_rules, resolve_pspec, tree_shardings,
)
from repro.models import transformer as tfm
from repro.serve.state import (
    InferenceState, inference_state_axes, new_inference_state, scatter_slot,
)


class InferenceEngine:
    """Sharded, donated prefill/decode step factory over request slots."""

    def __init__(self, cfg: ModelConfig, *, mesh=None, slots: int = 4,
                 max_len: int = 64, dtype=jnp.bfloat16,
                 rules: Optional[dict] = None, donate: bool = True,
                 explicit_shardings: bool = True):
        if not cfg.supports_decode():
            raise ValueError(f"{cfg.name} has no decode path")
        self.cfg = cfg
        self.slots = int(slots)
        self.max_len = int(max_len)
        self.dtype = dtype
        self.donate = donate
        # mesh and rules are built LAZILY, mirroring train.Engine: never
        # touch jax device state before the launcher injects XLA_FLAGS
        self._mesh = mesh
        self._rules = rules
        self._explicit = explicit_shardings
        self._axes = inference_state_axes(cfg)
        self._cache_axes = tfm.cache_axes(cfg)
        self._jit_cache: dict = {}

    @property
    def mesh(self):
        if self._mesh is None:
            from repro.launch.mesh import make_host_mesh
            self._mesh = make_host_mesh()
        return self._mesh

    @property
    def rules(self) -> dict:
        if self._rules is None:
            self._rules = make_rules(self.cfg, mesh=self.mesh)
        return self._rules

    # -- state lifecycle ---------------------------------------------------
    def init_state(self, params: Any) -> InferenceState:
        """Fresh InferenceState around ``params``, placed on its shardings.

        Takes OWNERSHIP of ``params`` (the buffers are donated through the
        jitted steps): when handing off a live TrainState the training side
        must be done with it, and when the shardings already match — the
        ``from_train_state`` path — the device_put is a no-op and the
        weights never return to host."""
        state = new_inference_state(params, self.cfg, slots=self.slots,
                                    max_len=self.max_len, dtype=self.dtype)
        if self._explicit:
            state = jax.device_put(state, self.state_shardings(state))
        return state

    @classmethod
    def from_train_state(cls, train_engine, train_state, *, slots: int = 4,
                         max_len: int = 64, dtype=jnp.bfloat16,
                         **kw) -> tuple["InferenceEngine", InferenceState]:
        """Adopt a trained ``TrainState`` from a ``train.Engine`` in place.

        The inference engine reuses the train engine's mesh; its rule table
        resolves the params to the same NamedShardings training used (the
        fsdp variant re-gathers shard-to-shard on device), so the returned
        InferenceState is built without a host round-trip.  The train state
        must not be stepped afterwards — its params are donated here."""
        eng = cls(train_engine.cfg, mesh=train_engine.mesh, slots=slots,
                  max_len=max_len, dtype=dtype, **kw)
        return eng, eng.init_state(train_state.params)

    def restore_params(self, path: str, example_params: Any) -> Any:
        """Params subtree of a full-TrainState .npz, restored into
        ``example_params`` — the CLI hand-off (``--ckpt`` from
        ``repro.launch.train``) without touching optimizer moments."""
        return ckpt.restore_subtree(path, example_params, prefix="params")

    # -- sharding resolution -----------------------------------------------
    def state_shardings(self, state: InferenceState) -> InferenceState:
        """NamedSharding tree matching ``state`` from the rule tables."""
        return tree_shardings(self._axes, state, self.mesh, self.rules)

    def _input_shardings(self, inputs: Dict[str, jax.Array]):
        out = {}
        for k, v in inputs.items():
            axes = ("batch",) + (None,) * (jnp.ndim(v) - 1)
            out[k] = NamedSharding(self.mesh, resolve_pspec(
                axes, jnp.shape(v), self.mesh, self.rules))
        return out

    # -- the two steps -----------------------------------------------------
    def _insert_fn(self, state: InferenceState, inputs: Dict[str, jax.Array],
                   slot: jax.Array):
        logits, cache_one = tfm.prefill(state.params, self.cfg, inputs,
                                        max_len=self.max_len,
                                        dtype=self.dtype)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)          # (1,)
        total = inputs["tokens"].shape[1] + (
            inputs["patches"].shape[1] if "patches" in inputs else 0)
        return InferenceState(
            params=state.params,
            cache=scatter_slot(self._cache_axes, state.cache, cache_one,
                               slot),
            positions=state.positions.at[slot].set(total),
            last_tok=state.last_tok.at[slot].set(tok[0]),
        ), tok

    def _decode_fn(self, state: InferenceState):
        logits, cache = tfm.decode_step(
            state.params, self.cfg, {"tokens": state.last_tok[:, None]},
            state.cache, state.positions, dtype=self.dtype)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)          # (slots,)
        return InferenceState(state.params, cache, state.positions + 1,
                              tok), tok

    def _get_jit(self, kind: str, state, inputs=None):
        key = (kind,) + (tuple(sorted(
            (k, tuple(jnp.shape(v)), str(v.dtype))
            for k, v in inputs.items())) if inputs else ())
        jfn = self._jit_cache.get(key)
        if jfn is None:
            donate = (0,) if self.donate else ()
            if not self._explicit:
                fn = self._insert_fn if kind == "insert" else self._decode_fn
                jfn = jax.jit(fn, donate_argnums=donate)
            else:
                st_sh = self.state_shardings(state)
                if kind == "insert":
                    jfn = jax.jit(
                        self._insert_fn,
                        in_shardings=(st_sh, self._input_shardings(inputs),
                                      None),
                        out_shardings=(st_sh, None),
                        donate_argnums=donate)
                else:
                    jfn = jax.jit(self._decode_fn,
                                  in_shardings=(st_sh,),
                                  out_shardings=(st_sh, None),
                                  donate_argnums=donate)
            self._jit_cache[key] = jfn
        return jfn

    def insert(self, state: InferenceState, inputs: Dict[str, jax.Array],
               slot: int):
        """Prefill ONE request (tokens (1, L), exact length — plus patches
        for VLM archs) into slot ``slot``.  Returns (state, first greedy
        token (1,)).  Jit-cached per distinct prompt shape."""
        inputs = {k: jnp.asarray(v) for k, v in inputs.items()}
        jfn = self._get_jit("insert", state, inputs)
        slot = jnp.asarray(slot, jnp.int32)
        if not self._explicit:
            return jfn(state, inputs, slot)
        with self.mesh, logical_sharding(self.mesh, self.rules):
            return jfn(state, inputs, slot)

    def decode(self, state: InferenceState):
        """One decode step over ALL slots: each slot's last token advances
        its own position counter.  Returns (state, greedy tokens (slots,));
        free slots produce garbage tokens the scheduler ignores."""
        jfn = self._get_jit("decode", state)
        if not self._explicit:
            return jfn(state)
        with self.mesh, logical_sharding(self.mesh, self.rules):
            return jfn(state)
