"""Per-request sampling over the fused serving steps.

Every decode/verify site in the engine used to be a bare ``jnp.argmax``.
This module supplies the two halves that replace it:

  * :class:`SamplingParams` — the host-side, per-request config
    (temperature / top-k / top-p / repetition-penalty / seed) the
    scheduler carries on each :class:`~repro.serve.scheduler.Request`
    and installs into the engine's per-slot arrays at admission;
  * :func:`draw` — the vectorized per-slot sampler the fused steps call:
    one (S, V) logits batch in, one (S,) token batch out, every slot
    applying ITS OWN parameters (heterogeneous configs coexist in one
    continuous batch).

DETERMINISM is the design center.  Each request owns a base PRNG key
derived from its seed alone, and the key used for the token at absolute
stream position ``p`` (position = the token's index in the slot's
combined patches+prompt+generated stream) is ``fold_in(base, p)`` — a
pure function of (seed, position), never of step count, batch
composition, slot id, chunking, or speculation depth.  Chunked prefill,
preemption swap-in (the position counter travels in the swap blob) and
prefix-cache resume therefore reproduce the exact draws of an
uninterrupted run, and the sampling-parity suite in
``tests/test_serve_sampling.py`` pins it.

``temperature <= 0`` means GREEDY: the slot takes the raw-logits argmax
(bit-identical to the pre-sampling serve path — the baseline every
existing parity test pins) and all other parameters are ignored.  The
whole sampling pipeline is further gated behind a single
``lax.cond(any(temperature > 0), ...)`` so an all-greedy batch never
pays the sort/softmax/categorical work.

The repetition penalty follows the HF convention (divide positive
logits by the penalty, multiply negative ones) over a per-slot boolean
PRESENCE row: token ids that appeared in the slot's context so far.
Prompt presence is written host-side at admission
(``engine.set_sampling``); each fused step folds the tokens it CONSUMES
as input into presence before sampling, so the mask always covers
exactly the tokens at stream positions below the one being drawn.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration.

    ``temperature <= 0`` selects the greedy path (raw-logits argmax,
    bit-identical to the pre-sampling engine); every other field is then
    ignored.  ``top_k == 0`` disables top-k; ``top_p == 1.0`` disables
    nucleus filtering; ``rep_penalty == 1.0`` disables the repetition
    penalty.  ``seed`` alone determines the request's draws."""
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    rep_penalty: float = 1.0
    seed: int = 0

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0

    def validate(self) -> None:
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.rep_penalty <= 0.0:
            raise ValueError(
                f"rep_penalty must be > 0, got {self.rep_penalty}")


def base_key(seed: int) -> np.ndarray:
    """The raw uint32 key data a request's seed expands to — what the
    engine stores in the per-slot ``sample_key`` row."""
    return np.asarray(jax.random.PRNGKey(int(seed)), np.uint32)


def presence_row(context, vocab: int) -> np.ndarray:
    """Boolean (vocab,) presence of ``context``'s token ids — the initial
    repetition-penalty mask a request's prompt installs at admission."""
    row = np.zeros((vocab,), bool)
    ids = np.asarray(context, np.int64).ravel()
    row[ids[(ids >= 0) & (ids < vocab)]] = True
    return row


def stream_digest(generated) -> str:
    """Order-independent 16-hex digest of a {rid: [token, ...]} result.

    The reproducibility handle the CLI prints and the CI smokes compare:
    two runs of the same (queue, params, seeds) must produce the same
    digest regardless of arrival order, slot assignment, chunking,
    preemption, speculation depth — and, with the two-tier prefix cache,
    regardless of WHERE each prompt's prefix was served from.  Draw keys
    fold by absolute stream position and a restored page carries its pos
    metadata inside the spill blob, so a cold prefill, a device-tier
    hit and a host-tier restore all reproduce bit-identical draws; the
    digest is the single value that pins it end to end."""
    return hashlib.sha256(json.dumps(
        {str(k): [int(t) for t in generated[k]] for k in sorted(generated)},
        sort_keys=True).encode()).hexdigest()[:16]


def draw(logits: jax.Array, *, keys: jax.Array, positions: jax.Array,
         temperature: jax.Array, top_k: jax.Array, top_p: jax.Array,
         rep_penalty: jax.Array, presence: jax.Array) -> jax.Array:
    """Sample one token per slot from ``logits`` (S, V), each slot under
    its own parameters, with the position-folded per-slot key.

    The pipeline (f32 throughout): repetition penalty over ``presence``,
    temperature scale, top-k cut, top-p (nucleus) cut over the surviving
    distribution, then a Gumbel categorical with
    ``fold_in(keys[s], positions[s])``.  Ties at the top-k/top-p
    threshold keep every tied token (deterministic, never fewer than the
    requested k / mass).  Callers gate on ``temperature > 0`` — this
    function itself always samples."""
    l = logits.astype(jnp.float32)
    V = l.shape[-1]
    pen = rep_penalty.astype(jnp.float32)[:, None]
    l = jnp.where(presence & (pen != 1.0),
                  jnp.where(l > 0, l / pen, l * pen), l)
    l = l / jnp.maximum(temperature.astype(jnp.float32), 1e-6)[:, None]
    # one descending sort serves both cuts
    sorted_l = jnp.sort(l, axis=-1)[:, ::-1]
    k = jnp.clip(jnp.where(top_k > 0, top_k, V), 1, V).astype(jnp.int32)
    kth = jnp.take_along_axis(sorted_l, (k - 1)[:, None], axis=-1)
    l = jnp.where(l < kth, -jnp.inf, l)
    sorted_l = jnp.where(sorted_l < kth, -jnp.inf, sorted_l)
    probs = jax.nn.softmax(sorted_l, axis=-1)
    csum = jnp.cumsum(probs, axis=-1)
    # a sorted token survives when the mass BEFORE it is still short of
    # top_p: the kept set is the smallest prefix reaching the target
    keep = (csum - probs) < top_p.astype(jnp.float32)[:, None]
    nkeep = jnp.maximum(jnp.sum(keep, axis=-1), 1).astype(jnp.int32)
    thr = jnp.take_along_axis(sorted_l, (nkeep - 1)[:, None], axis=-1)
    l = jnp.where(l < thr, -jnp.inf, l)
    folded = jax.vmap(jax.random.fold_in)(keys,
                                          positions.astype(jnp.uint32))
    return jax.vmap(jax.random.categorical)(folded, l).astype(jnp.int32)
