"""Continuous batching over the inference engine's request slots.

The scheduler owns the batching POLICY the engine deliberately excludes:
admit a pending request into any free slot, run the fused all-slot decode
step, harvest each active slot's token, and evict a slot the moment its
request finishes — EOS token or per-request ``max_new`` budget — so the
next pending request reuses it without reshaping the state.

Paged engines add two policy layers:

  * a host-side free list of physical pages — admission also claims
    ``ceil((patches + prompt + max_new) / page_size)`` pages for the slot
    (installed via ``engine.assign_pages``) and eviction returns them, so
    KV memory follows live tokens, not ``slots * max_len``;
  * an ADMISSION QUEUE for chunked prefill: a long prompt is inserted
    ``engine.prefill_chunk`` tokens at a time, one chunk per scheduler
    iteration, alternating with the fused all-slot decode step — admitting
    a long request no longer stalls in-flight decodes for the whole
    prompt's prefill.  ``stats["max_decode_gap_s"]`` records the worst
    stall in-flight decodes actually experienced.

PREFIX CACHE (``prefix_cache=True``): the free list grows into a
refcounted radix cache (:class:`RadixPagePool`).  A finished prefill
REGISTERS its full prompt pages under their token-prefix keys; a later
admission walks its prompt page by page against the trie and maps every
fully-matched page into its own table by bumping the page's refcount —
zero prefill compute and zero KV writes for the shared run, with prefill
resuming at the divergence offset through the ``insert_chunk`` /
``pos_start`` machinery.  A page is COPY-ON-WRITE duplicated only when
the admission must write inside a shared page (a prompt fully covered by
cached pages still re-inserts its final token for the first-token
logits).  Recurrent/SSM state is slot-major — not in pages — so on
hybrid archs the cache also stores a host-side recurrent snapshot per
registered page boundary and the resume offset is capped to boundaries
with a snapshot; replay genuinely starts at the divergence point.
Registration is INCREMENTAL: a chunked prefill registers each page the
moment its last token lands, so concurrent admissions match pages a
live slot still owns (refcount bump, CoW on divergence) — the cache
covers in-flight work, not only finished requests.

HOST TIER (``host_cache_bytes > 0``): the radix cache becomes two-tier.
When ``_reclaim`` would discard a cached (ref == 0) page, its KV rows —
and, on hybrid archs, the boundary's recurrent snapshot — are
``device_get`` into a byte-budgeted host-memory map under the same
page-granular prefix key.  A later admission extends its device-tier
match through ``host_match`` and each spilled page swaps back in by one
host-to-device scatter (``engine.restore_pages``) instead of
re-prefilling; the key moves back to the device tier in the same
transaction, so a prefix key lives in EXACTLY one tier at all times.
Eviction at both tiers is COST-AWARE, not LRU: victims are the keys
with the fewest admission-time hits (``_hits``, folded into
``lifetime_stats`` via the ``prefix_hits``/spill/restore counters),
oldest first on ties — pages are uniform size, so fewest-hits IS
lowest bytes-saved-per-hit.  When a matched run makes a plan
unfittable on a tight pool, admission degrades it page by page (host
tail first) down to a cold plan rather than deadlocking on a hit it
cannot afford.

PAGE-AWARE PREEMPTION (``preempt=True``): when admission would defer on
page exhaustion, the scheduler swaps out a victim slot — most recently
admitted first — by ``jax.device_get`` of just the victim's pool rows
plus its recurrent rows (``engine.swap_out``), frees its pages and slot,
and restores it later (``engine.swap_in``) when pages return.  A traffic
burst degrades tail latency instead of refusing admission, and every
stream stays bit-identical to the unpreempted run.

SPECULATIVE DECODING (``spec_k > 0``, paged engines): instead of one
token per fused step, each active slot asks a :class:`~repro.serve.
speculative.Drafter` for up to ``spec_k`` guessed next tokens and the
engine checks every guess in ONE ``verify`` forward, accepting the
longest prefix matching the model's own next tokens (plus the model's
next token itself).  Speculation is lossless for greedy AND sampled
slots — emitted streams are bit-identical to the ``spec_k == 0``
baseline (see ``engine._verify_fn``); acceptance only changes how many
tokens a step yields (``stats["spec_*"]``).

SAMPLING (``Request.sampling``): each request carries a
:class:`~repro.serve.sampling.SamplingParams`; admission installs it
into the engine's per-slot arrays (``engine.set_sampling``) so
heterogeneous configs — greedy and sampled — coexist in one fused
batch.  Draw keys fold by absolute stream position, so sampled streams
keep the same determinism contract as greedy ones.

Each slot's computation is independent of its neighbours (attention,
recurrent state and MoE routing are all per-row), so a request's
output is a function of (prompt, sampling params, seed) alone:
deterministic under any arrival order, slot assignment, co-batched
traffic, prefill chunking, preemption, or speculation depth — the
property ``tests/test_serve.py``, ``tests/test_serve_speculative.py``
and ``tests/test_serve_sampling.py`` pin.

``stats`` counts ONE call to :meth:`Scheduler.run`: it resets when a run
starts (a second batch is never polluted by the first's throughput or
``max_decode_gap_s``); ``lifetime_stats`` accumulates across runs.
"""
from __future__ import annotations

import time
from collections import Counter, OrderedDict, deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.registry import MetricRegistry
from repro.obs.trace import Tracer
from repro.serve.engine import InferenceEngine
from repro.serve.sampling import SamplingParams
from repro.serve.state import InferenceState


class PagePool:
    """Host-side free list of physical KV pages with conservation checking.

    Every admission (``alloc``) and eviction (``free``) moves pages
    between the free list and a per-slot ownership map, and every
    operation re-checks the invariant the hypothesis property test in
    ``tests/test_property.py`` drives: pages are never leaked, never
    double-owned, and ``available() + pages_in_tables() == num_pages``
    at all times.  Misuse fails loudly — ``alloc`` of an occupied slot
    or beyond capacity raises, ``free`` of an unowned slot raises."""

    def __init__(self, num_pages: int):
        self.num_pages = int(num_pages)
        self._free: deque = deque(range(self.num_pages))
        self._owned: Dict[int, List[int]] = {}

    def available(self) -> int:
        return len(self._free)

    def reclaimable(self, keep: Sequence[int] = ()) -> int:
        """Pages an admission needing ``keep`` could claim right now.
        Without refcounts every non-owned page is free, so this is just
        the free list; :class:`RadixPagePool` refines it."""
        return len(self._free)

    def pages_in_tables(self) -> int:
        return sum(len(p) for p in self._owned.values())

    def owner_slots(self):
        return set(self._owned)

    def alloc(self, slot: int, n: int) -> List[int]:
        if slot in self._owned:
            raise ValueError(f"slot {slot} already owns pages "
                             f"{self._owned[slot]} (double admission)")
        if n < 1:
            raise ValueError(f"slot {slot}: cannot allocate {n} pages")
        if n > len(self._free):
            raise ValueError(f"slot {slot}: wants {n} pages, only "
                             f"{len(self._free)} free (defer admission)")
        pages = [self._free.popleft() for _ in range(n)]
        self._owned[slot] = pages
        self._check()
        return pages

    def free(self, slot: int) -> List[int]:
        if slot not in self._owned:
            raise KeyError(f"slot {slot} owns no pages (double free?)")
        pages = self._owned.pop(slot)
        self._free.extend(pages)
        self._check()
        return pages

    def table(self, slot: int) -> List[int]:
        """The ordered page run ``slot`` currently owns (its page row)."""
        return list(self._owned[slot])

    def _check(self) -> None:
        seen = list(self._free) + [p for ps in self._owned.values()
                                   for p in ps]
        assert len(seen) == len(set(seen)) == self.num_pages, \
            f"page conservation broken: {len(set(seen))} distinct of " \
            f"{len(seen)} tracked vs {self.num_pages} total"


class RadixPagePool(PagePool):
    """Refcounted radix/prefix cache over the physical page pool, with an
    optional host-memory spill tier.

    Every page is in exactly one of three states:

      * FREE        — on the free list, content meaningless;
      * IN USE      — mapped by >= 1 slot page tables; ``refcount(p)`` ==
                      the number of slots mapping it (1 = private,
                      > 1 = shared);
      * CACHED      — refcount 0 but REGISTERED in the radix trie: its KV
                      content backs a token-prefix key and can be mapped
                      by a future admission (refcount bump, zero prefill).
                      Cached pages are reclaimed on demand when the free
                      list runs short, unregistering their keys.

    The trie is host-side and page-granular: key = the full token prefix
    up to a page boundary, value = the physical page holding that page's
    KV.  ``match`` walks a prompt boundary by boundary; ``admit`` maps the
    matched run plus fresh tail pages into a slot in one transaction, with
    copy-on-write replacing any shared page the slot must write into.
    ``register`` inserts a prefill's completed prompt pages (plus optional
    per-boundary recurrent snapshots for hybrid archs) — incrementally at
    each chunk, so pages owned by a still-prefilling live slot are already
    matchable by concurrent admissions.

    THE HOST TIER (``host_bytes > 0``): a reclaimed cached page is no
    longer simply lost — ``_reclaim`` first spills its KV content (and
    its recurrent snapshot, when one is registered) into a host-memory
    dict keyed by the same prefix tuple, via the ``spill_fn`` the
    scheduler installs.  ``host_match`` continues a prompt's prefix walk
    past the device trie into the spilled keys, and ``admit`` swaps a
    matched host entry back into a freshly-claimed page (the scheduler
    scatters the blob — ``engine.restore_pages`` — the same mechanics as
    a preemption ``swap_in``), re-registering the key device-side.  A
    prefix key therefore lives in EXACTLY ONE tier at a time: spilled ∪
    device-registered keys are disjoint, and a spill/restore round trip
    conserves the cached bytes it moves.

    EVICTION is cost-aware at both tiers, replacing plain LRU: every
    admit that maps a key (device bump or host restore) increments the
    key's hit counter, and the victim is the key with the FEWEST hits,
    oldest first among ties — bytes-saved-per-hit collapses to the hit
    count because every page holds the same ``page_size`` tokens of KV.
    The counters live on the pool (they survive ``Scheduler.run``
    boundaries, like ``lifetime_stats``); the per-run spill/restore
    totals drain into the scheduler's stats via ``drain_events``.

    PR 5's conservation invariant generalizes: free + cached + in-use
    partition the pool exactly, and the sum of refcounts equals the total
    page-table occupancy (``pages_in_tables``) — re-checked after every
    operation and driven by the hypothesis test in ``test_property.py``,
    which also pins the two-tier key disjointness and the host byte
    budget."""

    def __init__(self, num_pages: int, page_size: int, *,
                 host_bytes: int = 0):
        super().__init__(num_pages)
        self.page_size = int(page_size)
        self._ref: Dict[int, int] = {}              # page -> #owning slots
        self._trie: Dict[Tuple[int, ...], int] = {}  # prefix key -> page
        self._key: Dict[int, Tuple[int, ...]] = {}   # page -> its key
        self._cached: "OrderedDict[int, None]" = OrderedDict()  # recency
        self._snaps: Dict[Tuple[int, ...], Any] = {}  # key -> rec snapshot
        #: host spill tier: prefix key -> {"kv": blob, "snap": ..,
        #: "nbytes": int}, in recency order; capped at ``host_bytes``
        self.host_bytes = int(host_bytes)
        self._host: "OrderedDict[Tuple[int, ...], Dict[str, Any]]" = \
            OrderedDict()
        self._host_used = 0
        self._spill_fn = None       # page -> kv blob (engine.spill_page)
        #: per-key hit counters — the cost-aware eviction signal at BOTH
        #: tiers; lifetime by construction (never reset between runs)
        self._hits: Dict[Tuple[int, ...], int] = {}
        #: per-run spill/evict totals the scheduler drains into its stats
        self._events: Dict[str, int] = {"host_spilled_pages": 0,
                                        "host_evicted_pages": 0}

    def set_spill_fn(self, fn) -> None:
        """Install the page-content gather the spill path calls (the
        scheduler binds ``engine.spill_page`` over its live state); the
        host tier stays inert without one even when ``host_bytes > 0``."""
        self._spill_fn = fn

    # -- accounting --------------------------------------------------------
    def available(self) -> int:
        """Pages an admission can claim: free now + cached-reclaimable."""
        return len(self._free) + len(self._cached)

    def cached_pages(self) -> int:
        return len(self._cached)

    def in_use_pages(self) -> set:
        return set(self._ref)

    def host_pages(self) -> int:
        """Spilled prefix pages currently held in the host tier."""
        return len(self._host)

    def host_used_bytes(self) -> int:
        return self._host_used

    def spilled_keys(self) -> set:
        """The prefix keys the host tier currently backs (always disjoint
        from the device trie's keys — a key lives in exactly one tier)."""
        return set(self._host)

    def hit_count(self, key: Tuple[int, ...]) -> int:
        """Lifetime admit-time hits on ``key`` — the cost-aware eviction
        signal (a key that keeps saving prefill outlives colder ones)."""
        return self._hits.get(key, 0)

    def drain_events(self) -> Dict[str, int]:
        """Return and reset the spill/evict counters accumulated since
        the last drain — folded into the scheduler's per-run stats."""
        out = dict(self._events)
        for k in self._events:
            self._events[k] = 0
        return out

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    def reclaimable(self, keep: Sequence[int] = ()) -> int:
        """Free pages plus cached (ref-0) pages OUTSIDE ``keep`` — what an
        admission that wants to map the ``keep`` run can actually claim."""
        ks = set(keep)
        return len(self._free) + sum(1 for p in self._cached if p not in ks)

    def can_admit(self, shared: Sequence[int], n_fresh: int) -> bool:
        """True when ``n_fresh`` pages can be claimed without reclaiming
        any of the ``shared`` pages the same admission wants to map."""
        return n_fresh <= self.reclaimable(shared)

    # -- the prefix walk ---------------------------------------------------
    def match(self, prompt) -> Tuple[List[int], int]:
        """Longest run of registered full pages covering ``prompt``'s
        prefix: ([physical pages], matched token count).  Touches the LRU
        so a hot prefix survives pool pressure."""
        ps = self.page_size
        prompt = [int(t) for t in np.asarray(prompt).ravel()]
        pages: List[int] = []
        for i in range(len(prompt) // ps):
            p = self._trie.get(tuple(prompt[:(i + 1) * ps]))
            if p is None:
                break
            pages.append(p)
            if p in self._cached:
                self._cached.move_to_end(p)
        return pages, len(pages) * ps

    def host_match(self, prompt, start_pages: int) -> List[Tuple[int, ...]]:
        """Host-tier continuation of a device ``match``: the prefix keys
        for page boundaries ``start_pages``, ``start_pages + 1``, ... as
        long as the host tier holds them.  Touches recency so a hot
        spilled prefix outlives colder ones under the byte budget."""
        ps = self.page_size
        prompt = [int(t) for t in np.asarray(prompt).ravel()]
        keys: List[Tuple[int, ...]] = []
        for i in range(int(start_pages), len(prompt) // ps):
            key = tuple(prompt[:(i + 1) * ps])
            if key not in self._host:
                break
            keys.append(key)
            self._host.move_to_end(key)
        return keys

    def snapshot(self, key: Tuple[int, ...]):
        """The recurrent-state snapshot registered at prefix ``key``."""
        return self._snaps[key]

    def has_snapshot(self, key: Tuple[int, ...]) -> bool:
        return key in self._snaps

    # -- transactions ------------------------------------------------------
    def _pick_victim(self) -> int:
        """Cost-aware device-tier eviction: the cached page whose key has
        saved the least prefill (fewest admit-time hits), oldest first
        among ties — bytes-saved-per-hit reduces to the hit count since
        every page holds the same ``page_size`` tokens of KV."""
        victim, vh = None, None
        for p in self._cached:              # insertion order: oldest first
            h = self._hits.get(self._key[p], 0)
            if vh is None or h < vh:
                victim, vh = p, h
                if h == 0:                  # cannot score lower
                    break
        return victim

    def _host_evict_one(self) -> None:
        """Cost-aware host-tier eviction under the byte budget: fewest
        hits first, oldest first among ties (same rule as the device
        tier — the two tiers share one hit-counter table)."""
        victim, vh = None, None
        for k in self._host:                # insertion order: oldest first
            h = self._hits.get(k, 0)
            if vh is None or h < vh:
                victim, vh = k, h
                if h == 0:
                    break
        self._host_used -= self._host.pop(victim)["nbytes"]
        self._events["host_evicted_pages"] += 1

    def _host_insert(self, key: Tuple[int, ...], kv: list, snap) -> None:
        """Spill one evicted page's content into the host tier, evicting
        colder entries until the byte budget holds (an entry larger than
        the whole budget is simply dropped)."""
        nbytes = sum(int(r.nbytes) for r in kv if r is not None)
        if snap is not None:
            nbytes += sum(int(r.nbytes) for r in snap if r is not None)
        if nbytes > self.host_bytes:
            return
        while self._host_used + nbytes > self.host_bytes:
            self._host_evict_one()
        self._host[key] = {"kv": kv, "snap": snap, "nbytes": nbytes}
        self._host_used += nbytes
        self._events["host_spilled_pages"] += 1

    def _drop_host(self, key: Tuple[int, ...]) -> None:
        """Remove ``key``'s host entry (a device registration supersedes
        it — the two tiers must stay disjoint)."""
        ent = self._host.pop(key, None)
        if ent is not None:
            self._host_used -= ent["nbytes"]
            self._events["host_evicted_pages"] += 1

    def _reclaim(self, n: int) -> None:
        """Grow the free list to ``n`` pages by evicting cached (ref-0)
        pages fewest-hits-first, unregistering their keys and snapshots —
        spilling each victim's KV content (and snapshot) into the host
        tier first when one is configured."""
        while len(self._free) < n:
            if not self._cached:
                raise ValueError(f"want {n} free pages, only "
                                 f"{len(self._free)} free and nothing "
                                 f"cached to reclaim (defer admission)")
            p = self._pick_victim()
            del self._cached[p]
            key = self._key.pop(p)
            del self._trie[key]
            snap = self._snaps.pop(key, None)
            if self.host_bytes and self._spill_fn is not None:
                self._host_insert(key, self._spill_fn(p), snap)
            self._free.append(p)

    def alloc(self, slot: int, n: int) -> List[int]:
        """Claim ``n`` fresh private pages (no sharing) — the cold path
        and the preemption-restore path."""
        if slot in self._owned:
            raise ValueError(f"slot {slot} already owns pages "
                             f"{self._owned[slot]} (double admission)")
        if n < 1:
            raise ValueError(f"slot {slot}: cannot allocate {n} pages")
        if n > self.available():
            raise ValueError(f"slot {slot}: wants {n} pages, only "
                             f"{self.available()} free/cached "
                             f"(defer admission)")
        self._reclaim(n)
        pages = [self._free.popleft() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        self._owned[slot] = pages
        self._check()
        return pages

    def admit(self, slot: int, shared: Sequence[int], n_tail: int,
              cow_idx: Sequence[int] = (),
              host_keys: Sequence[Tuple[int, ...]] = (),
              n_host_reg: Optional[int] = None):
        """Map ``shared`` (refcount bump each), then one freshly-claimed
        page per spilled ``host_keys`` entry, then ``n_tail`` fresh tail
        pages into ``slot``'s table, copy-on-writing the shared pages at
        indices ``cow_idx`` (the ones the slot must write into).

        Each host key's entry is consumed from the spill tier and its
        first ``n_host_reg`` pages are RE-REGISTERED device-side (key ->
        new page, snapshot back into the snap table) — the key moves back
        to the device tier in the same transaction, keeping the tiers
        disjoint.  The scheduler excludes the final restored page from
        re-registration when the prefill resume point writes into it
        (the content is re-registered at prefill completion instead, the
        same rule CoW enforces for device-shared pages).

        Returns ``(cow_pairs, restored)``: the (src, dst) CoW pairs to
        clone device-side, and ``(page, entry)`` per host key — the
        scheduler scatters ``entry["kv"]`` into ``page``
        (``engine.restore_pages``).  Every mapped key's hit counter is
        bumped here — admit time, not match time, so deferred admissions
        re-planning each cycle cannot inflate the eviction signal."""
        if slot in self._owned:
            raise ValueError(f"slot {slot} already owns pages "
                             f"{self._owned[slot]} (double admission)")
        host_keys = list(host_keys)
        if n_host_reg is None:
            n_host_reg = len(host_keys)
        n_fresh = n_tail + len(cow_idx) + len(host_keys)
        if not self.can_admit(shared, n_fresh):
            raise ValueError(f"slot {slot}: wants {n_fresh} fresh pages "
                             f"beyond the {len(shared)} shared ones "
                             f"(defer admission)")
        for p in shared:
            if p not in self._ref and p not in self._cached:
                raise ValueError(f"page {p} is neither in use nor cached "
                                 f"(stale match?)")
        for k in host_keys:
            if k not in self._host:
                raise ValueError("host-tier key vanished between match "
                                 "and admit (stale match?)")
        owned = list(shared)
        for p in owned:                     # bump before reclaiming so the
            if p in self._cached:           # shared run cannot be evicted
                del self._cached[p]         # out from under this admission
            self._ref[p] = self._ref.get(p, 0) + 1
            key = self._key.get(p)
            if key is not None:
                self._hits[key] = self._hits.get(key, 0) + 1
        self._owned[slot] = owned           # _release needs ownership set
        # consume the host entries BEFORE reclaiming: _reclaim spills its
        # victims into the host tier, and those inserts evict cold keys —
        # possibly the very ones this admission is restoring
        ents = []
        for key in host_keys:
            ent = self._host.pop(key)
            self._host_used -= ent["nbytes"]
            ents.append(ent)
        self._reclaim(n_fresh)
        restored = []
        for j, (key, ent) in enumerate(zip(host_keys, ents)):
            p = self._free.popleft()
            self._ref[p] = 1
            owned.append(p)
            if j < n_host_reg:              # the key returns device-side
                self._trie[key] = p
                self._key[p] = key
                if ent["snap"] is not None:
                    self._snaps[key] = ent["snap"]
            self._hits[key] = self._hits.get(key, 0) + 1
            restored.append((p, ent))
        cow_pairs = []
        for i in cow_idx:
            src, dst = owned[i], self._free.popleft()
            self._release_one(src)
            self._ref[dst] = 1
            owned[i] = dst
            cow_pairs.append((src, dst))
        for _ in range(n_tail):
            p = self._free.popleft()
            self._ref[p] = 1
            owned.append(p)
        self._check()
        return cow_pairs, restored

    def _release_one(self, p: int) -> None:
        """Drop one reference to ``p``; a last owner leaves it CACHED when
        registered (its content still backs a trie key), FREE otherwise."""
        self._ref[p] -= 1
        if self._ref[p] == 0:
            del self._ref[p]
            if p in self._key:
                self._cached[p] = None      # LRU tail = most recent
            else:
                self._free.append(p)

    def free(self, slot: int) -> List[int]:
        if slot not in self._owned:
            raise KeyError(f"slot {slot} owns no pages (double free?)")
        pages = self._owned.pop(slot)
        for p in pages:
            self._release_one(p)
        self._check()
        return pages

    def register(self, slot: int, prompt, snaps: Optional[Dict] = None,
                 up_to: Optional[int] = None):
        """Insert ``slot``'s completed prompt pages into the trie (key =
        token prefix up to each page boundary).  Keys already registered
        keep their original page.  ``snaps`` maps page-boundary index
        (1-based page count) to a recurrent snapshot; when given, a
        boundary WITHOUT a snapshot is skipped — a hybrid arch must never
        match a prefix it cannot resume from.  ``up_to`` caps
        registration at the first ``up_to`` prompt tokens: a chunked
        prefill registers each page the moment its last token lands, so
        concurrent admissions match pages a LIVE slot still owns
        (refcount bump on those in-use pages, CoW on divergence) instead
        of waiting for the whole prefill to finish.  A registered key
        supersedes any host-tier copy (the tiers stay disjoint)."""
        ps = self.page_size
        prompt = [int(t) for t in np.asarray(prompt).ravel()]
        limit = len(prompt) if up_to is None else min(int(up_to),
                                                      len(prompt))
        owned = self._owned[slot]
        for i in range(min(limit // ps, len(owned))):
            key = tuple(prompt[:(i + 1) * ps])
            if key in self._trie:
                continue
            if snaps is not None and (i + 1) not in snaps:
                continue
            p = owned[i]
            if p in self._key:              # already backs another prefix
                continue
            self._trie[key] = p
            self._key[p] = key
            if snaps is not None:
                self._snaps[key] = snaps[i + 1]
            self._drop_host(key)
        self._check()

    # -- the generalized conservation invariant ----------------------------
    def _check(self) -> None:
        owned = [p for ps in self._owned.values() for p in ps]
        for slot, ps in self._owned.items():
            assert len(ps) == len(set(ps)), \
                f"slot {slot} maps page(s) twice: {ps}"
        counts = dict(Counter(owned))
        assert counts == self._ref, \
            f"refcounts {self._ref} != table occupancy {counts}"
        fr, ca, iu = set(self._free), set(self._cached), set(self._ref)
        assert len(self._free) == len(fr), "free list holds duplicates"
        assert not (fr & ca) and not (fr & iu) and not (ca & iu), \
            "page in two ownership states at once"
        assert fr | ca | iu == set(range(self.num_pages)), \
            f"page conservation broken: {len(fr)} free + {len(ca)} " \
            f"cached + {len(iu)} in use != {self.num_pages} total"
        assert sum(self._ref.values()) == self.pages_in_tables()
        assert {p: k for k, p in self._trie.items()} == self._key, \
            "trie and reverse key map diverged"
        assert ca <= set(self._key), "cached page without a trie key"
        assert set(self._snaps) <= set(self._trie), \
            "snapshot for an unregistered prefix"
        # the host-tier half: a prefix key lives in exactly one tier, and
        # the byte accounting is exact under the budget
        assert not (set(self._host) & set(self._trie)), \
            "prefix key registered in both tiers at once"
        assert self._host_used == sum(e["nbytes"]
                                      for e in self._host.values()), \
            "host-tier byte accounting drifted"
        assert self._host_used <= max(self.host_bytes, 0), \
            "host tier exceeds its byte budget"


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                      # (L,) int32 token ids
    max_new: int = 16
    extras: Dict[str, np.ndarray] = field(default_factory=dict)  # e.g. patches
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None              # last slot served in (telemetry)
    #: per-request sampling config; the default is the greedy path
    sampling: SamplingParams = field(default_factory=SamplingParams)


@dataclass
class _Admission:
    """A request whose prompt is being chunk-prefilled into its slot.

    ``cursor`` starts at the prefix-cache resume offset (0 on a cold
    admission); ``capture`` asks each page-boundary chunk to snapshot the
    slot's recurrent state so the finished prompt can register resumable
    prefixes on hybrid archs."""
    r: Request
    slot: int
    cursor: int = 0                         # prompt tokens inserted so far
    capture: bool = False                   # snapshot recurrent state at
    snaps: Dict[int, Any] = field(default_factory=dict)  # page boundaries


@dataclass
class _AdmitPlan:
    """Host-side page plan for one paged admission: how much of the prompt
    the prefix cache already holds (device pages to map, host-tier keys to
    swap back in) and what must be claimed fresh."""
    total: int                              # pages the slot will own
    shared: List[int] = field(default_factory=list)  # matched cached pages
    resume: int = 0                         # prefill resumes at this token
    cow_idx: List[int] = field(default_factory=list)  # shared idx to CoW
    snap_key: Optional[Tuple[int, ...]] = None  # recurrent snapshot to load
    #: spilled prefix keys continuing the device run — each restores into
    #: a freshly-claimed page instead of re-prefilling
    host_keys: List[Tuple[int, ...]] = field(default_factory=list)
    #: how many of ``host_keys`` re-register device-side (all but a final
    #: restored page the resume point writes into)
    n_host_reg: int = 0

    @property
    def fresh_needed(self) -> int:
        # host-restored pages claim from the free list like the tail does,
        # so they are already inside ``total - len(shared)``
        return self.total - len(self.shared) + len(self.cow_idx)


@dataclass
class _Swapped:
    """A preempted request: its host-side swap blob awaiting restore."""
    r: Request
    blob: Dict[str, Any]
    n_pages: int


#: the scheduler's per-run stat family — one ``MetricRegistry`` StatGroup
#: per scheduler under ``sched.run.*`` (and its lifetime twin under
#: ``sched.lifetime.*``), keeping the historical flat-dict API
_STAT_DEFAULTS: Dict[str, float] = {
    "prefill_tokens": 0, "prefill_s": 0.0, "prefill_chunks": 0,
    "decode_tokens": 0, "decode_s": 0.0, "decode_steps": 0,
    # slot-steps: sum over fused rounds of |active slots| — the
    # denominator for accepted-tokens-per-step (== decode_tokens
    # without speculation; smaller when drafts are accepted)
    "decode_slot_steps": 0,
    # worst single stall; the full distribution lives in the
    # ``serve.decode_gap_s`` histogram (``Scheduler.decode_gaps``)
    "max_decode_gap_s": 0.0,
    # speculative counters: proposed drafts, drafts accepted,
    # verify rounds (a subset of decode_steps)
    "spec_proposed": 0, "spec_accepted": 0, "spec_steps": 0,
    # admission-pressure counters: total defer cycles across
    # requests, and the worst single request's defer count
    "deferred_admissions": 0, "max_defer_cycles": 0,
    # prefix-cache counters: admissions that consulted the
    # trie, admissions that mapped >= 1 cached page, prefill
    # tokens skipped by resuming past the shared run, and
    # pages copy-on-write duplicated
    "prefix_lookups": 0, "prefix_hits": 0,
    "prefix_hit_tokens": 0, "cow_pages": 0,
    # host spill tier: admissions that swapped >= 1 spilled
    # page back in, the pages and prefill tokens those swaps
    # covered, and the pool's spill/evict traffic (drained
    # from RadixPagePool at the end of each run)
    "host_hits": 0, "host_restored_pages": 0,
    "host_hit_tokens": 0, "host_spilled_pages": 0,
    "host_evicted_pages": 0,
    # page-aware preemption: victims swapped to host, swapped
    # requests restored into a slot
    "preemptions": 0, "restores": 0}


class Scheduler:
    """Drives an :class:`InferenceEngine` over a queue of requests."""

    def __init__(self, engine: InferenceEngine, state: InferenceState, *,
                 eos_id: Optional[int] = None, spec_k: int = 0,
                 drafter=None, prefix_cache: bool = False,
                 preempt: bool = False, host_cache_bytes: int = 0,
                 registry: Optional[MetricRegistry] = None,
                 tracer: Optional[Tracer] = None):
        self.engine = engine
        self.state = state
        self.eos_id = eos_id
        self.spec_k = int(spec_k)
        if self.spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        if self.spec_k and not engine.paged:
            raise ValueError("speculative decoding runs over the paged KV "
                             "pool; spec_k > 0 requires paged=True "
                             "(spec_k=0 is the parity baseline)")
        self.prefix_cache = bool(prefix_cache)
        self.preempt = bool(preempt)
        self.host_cache_bytes = int(host_cache_bytes)
        if (self.prefix_cache or self.preempt) and not engine.paged:
            raise ValueError("prefix_cache/preempt are page-pool policies; "
                             "both require paged=True")
        if self.host_cache_bytes and not self.prefix_cache:
            raise ValueError("host_cache_bytes spills evicted prefix-cache "
                             "pages to host memory; it requires "
                             "prefix_cache=True")
        if self.spec_k and drafter is None:
            from repro.serve.speculative import NgramDrafter
            drafter = NgramDrafter()
        self.drafter = drafter
        #: per-slot rid history — lets tests assert slots are actually reused
        self.slot_history: Dict[int, List[int]] = {
            s: [] for s in range(engine.slots)}
        #: telemetry: every measurement lands in the registry (pass one in
        #: to share a store across schedulers/launchers) and every phase
        #: emits a span on the tracer — both pure host-side, so enabling
        #: them cannot perturb emitted streams (``tests/test_obs.py``)
        self.registry = registry if registry is not None else MetricRegistry()
        self.tracer = tracer if tracer is not None else Tracer()
        #: the historical flat per-run stats dict, now a registry StatGroup
        #: view: same dict API (``stats[k] += v``, ``dict(stats)``), every
        #: key visible to snapshot/dump as ``sched.run.<key>``
        self.stats = self.registry.group("sched.run", _STAT_DEFAULTS)
        #: accumulated across every finished/aborted run() on this scheduler
        self.lifetime_stats = self.registry.group("sched.lifetime",
                                                  _STAT_DEFAULTS)
        #: decode-gap DISTRIBUTION (the stall metric): per run, like
        #: ``stats`` — ``decode_gaps.quantile(99)`` replaces eyeballing
        #: the ``max_decode_gap_s`` scalar (which stays, as the p100)
        self.decode_gaps = self.registry.histogram("serve.decode_gap_s")
        if engine.paged:
            if self.prefix_cache:
                self._pages = RadixPagePool(
                    engine.num_pages, engine.page_size,
                    host_bytes=self.host_cache_bytes)

                # the spill hook closes over the live state: by the time
                # _reclaim fires the scheduler's state IS the engine state
                def _spill(page):
                    with self.tracer.span("spill", page=page):
                        return self.engine.spill_page(self.state, page)

                self._pages.set_spill_fn(_spill)
            else:
                self._pages = PagePool(engine.num_pages)
        else:
            self._pages = None
        self._last_decode_t: Optional[float] = None
        #: per-request time-to-first-token for the current run (seconds
        #: from run() start to the request's first generated token) — a
        #: registry Series, so the plain dict API callers index is the
        #: same store the metrics dump reports as ``serve.ttft_s``
        self.ttft = self.registry.series("serve.ttft_s")
        self._run_t0: float = 0.0
        self._rid_open: Dict[int, Optional[int]] = {}  # rid -> open span
        self._defer_counts: Dict[int, int] = {}
        self._admit_seq: Dict[int, int] = {}   # slot -> admission sequence
        self._seq = 0
        #: global admission/restore completion order for the current run
        #: (rid per event; a preempted rid appears once per restore) —
        #: what the fairness regression tests assert on
        self.admission_order: List[int] = []
        # slots whose per-slot sampling rows were left non-greedy: a later
        # greedy admission must reset them, while greedy-into-greedy slot
        # reuse skips the host round-trip entirely (the default rows are
        # already greedy)
        self._sampled_slots: set = set()

    def _drain_pool_events(self) -> None:
        """Fold the pool's spill/evict event counters into this run's
        stats.  Spills happen inside ``_reclaim`` — under some OTHER
        request's admission — so the pool accumulates them off to the
        side and the scheduler drains them once per run, right before the
        lifetime fold (the cost-aware eviction's input signal)."""
        if isinstance(self._pages, RadixPagePool):
            for k, v in self._pages.drain_events().items():
                self.stats[k] += v

    def _fold_lifetime(self) -> None:
        for k, v in self.stats.items():
            if k in ("max_decode_gap_s", "max_defer_cycles"):  # maxima
                self.lifetime_stats[k] = max(self.lifetime_stats[k], v)
            else:
                self.lifetime_stats[k] += v

    def _done(self, r: Request) -> bool:
        if not r.generated:
            return False
        if self.eos_id is not None and r.generated[-1] == self.eos_id:
            return True
        return len(r.generated) >= r.max_new

    # -- admission ---------------------------------------------------------
    def _total_len(self, r: Request) -> int:
        patches = int(np.shape(r.extras["patches"])[0]) \
            if "patches" in r.extras else 0
        return patches + len(np.asarray(r.prompt)) + r.max_new

    def _pages_needed(self, r: Request) -> int:
        return -(-self._total_len(r) // self.engine.page_size)

    def _validate(self, r: Request) -> None:
        try:
            r.sampling.validate()
        except ValueError as e:
            raise ValueError(f"request {r.rid}: {e}") from None
        if r.max_new < 1:
            # the prefill itself emits the first greedy token, so a budget
            # below one token is unservable rather than silently exceeded
            raise ValueError(f"request {r.rid}: max_new must be >= 1")
        total = self._total_len(r)
        if total > self.engine.max_len:
            raise ValueError(
                f"request {r.rid}: patches + prompt + max_new = {total} "
                f"exceeds engine max_len {self.engine.max_len} (the cache "
                f"would wrap and overwrite live context)")
        if self.engine.paged and self._pages_needed(r) > self.engine.num_pages:
            raise ValueError(
                f"request {r.rid}: needs {self._pages_needed(r)} pages but "
                f"the pool only has {self.engine.num_pages}")

    def _alloc_pages(self, r: Request, slot: int) -> None:
        pages = self._pages.alloc(slot, self._pages_needed(r))
        self.state = self.engine.assign_pages(self.state, slot, pages)

    def _plan(self, r: Request,
              max_run: Optional[int] = None) -> _AdmitPlan:
        """Page plan for admitting ``r``: walk the prefix cache (when on)
        across BOTH tiers — the device trie first, then the host spill
        tier continuing from where the trie walk broke — and decide the
        shared run, the prefill resume offset, and which shared pages
        must be copy-on-write duplicated.  ``max_run`` caps the combined
        matched run (host tail dropped first): the admission loop
        degrades an unfittable plan page by page down to a cold admission
        instead of deferring forever on a pool too tight to both KEEP the
        shared run and claim the fresh pages around it."""
        total = self._pages_needed(r)
        if not self.prefix_cache or "patches" in r.extras:
            return _AdmitPlan(total)
        prompt = np.asarray(r.prompt, np.int32).ravel()
        with self.tracer.span("prefix_match", rid=r.rid):
            shared, matched = self._pages.match(prompt)
            host_keys = self._pages.host_match(prompt, len(shared))
        ps = self.engine.page_size
        cap = len(shared) + len(host_keys)
        if max_run is not None:
            cap = min(cap, max_run)
        if self.engine.has_recurrent_state:
            # recurrent/SSM state lives in slot rows, not pages: resume
            # only from a boundary with a registered snapshot, and always
            # keep >= 1 prompt token to re-insert (the first-token logits
            # come out of the prefill) — so the resume point is a boundary
            # and no shared page is ever written into (no CoW needed).
            # Spilled entries carry their boundary snapshot, so a host
            # key is as resumable as a device one.
            cap = min(cap, (len(prompt) - 1) // ps)
        if cap <= len(shared):
            shared, host_keys = shared[:cap], []
        else:
            host_keys = host_keys[:cap - len(shared)]
        if not shared and not host_keys:
            return _AdmitPlan(total)
        matched = cap * ps
        resume = min(matched, len(prompt) - 1)
        # a prompt fully covered by cached pages still re-inserts its last
        # token for the first-token logits: that write lands INSIDE the
        # final matched page — a device-shared page needs a private CoW
        # copy; a host-restored page is already private, so it is simply
        # left unregistered until prefill completion re-registers it
        cow_idx = list(range(resume // ps, len(shared)))
        snap_key = tuple(int(t) for t in prompt[:resume]) \
            if self.engine.has_recurrent_state else None
        n_host_reg = min(len(host_keys),
                         max(0, resume // ps - len(shared)))
        return _AdmitPlan(total, list(shared), resume, cow_idx, snap_key,
                          host_keys, n_host_reg)

    def _fits(self, plan: _AdmitPlan, reserve: int = 0) -> bool:
        """Can ``plan`` be claimed while leaving ``reserve`` pages
        untouched?  ``reserve`` is the parked restore head's page need —
        pending admissions must not starve it out of the headroom it is
        owed (see the restore phase in :meth:`_run`)."""
        if isinstance(self._pages, RadixPagePool):
            return self._pages.can_admit(plan.shared,
                                         plan.fresh_needed + reserve)
        return self._pages.available() >= plan.total + reserve

    def _preempt_gain(self, active: Dict[int, "Request"],
                      plan: _AdmitPlan) -> int:
        """Pages that preempting EVERY active slot would actually return
        to the claimable set.  Under the prefix cache a page only leaves
        the in-use state when its refcount drops to 0, so pages shared
        with a non-preemptable owner (a mid-chunk admission, or the
        plan's own shared run) must not be counted — the old bound
        ``sum(len(table(s)))`` overcounted exactly those, letting the
        scheduler swap out every victim and still defer (a preemption
        storm with zero admission progress)."""
        tables = [self._pages.table(s) for s in active]
        if not isinstance(self._pages, RadixPagePool):
            return sum(len(t) for t in tables)
        refs = Counter(p for t in tables for p in t)
        keep = set(plan.shared)
        return sum(1 for p, c in refs.items()
                   if p not in keep and self._pages.refcount(p) == c)

    def _claim_pages(self, r: Request, slot: int, plan: _AdmitPlan) -> None:
        """Execute ``plan``: map shared + restored + fresh pages into
        ``slot``'s page table, scatter host-tier spill blobs back into
        the restored pages, clone CoW pages device-side, and load the
        recurrent snapshot the resume point needs (hybrid archs)."""
        if not isinstance(self._pages, RadixPagePool):
            self._alloc_pages(r, slot)
            return
        n_tail = plan.total - len(plan.shared) - len(plan.host_keys)
        cow_pairs, restored = self._pages.admit(
            slot, plan.shared, n_tail, plan.cow_idx,
            host_keys=plan.host_keys, n_host_reg=plan.n_host_reg)
        row = self._pages.table(slot)
        keep = set(plan.shared) - {s for s, _ in cow_pairs}
        # only non-shared pages get their pos metadata cleared: the shared
        # run's pos entries ARE the cached KV's validity record (restored
        # pages are cleared, then fully overwritten by the scatter below)
        fresh = [p for p in row if p not in keep]
        self.state = self.engine.assign_pages(self.state, slot, row,
                                              fresh=fresh)
        if restored:
            # the host-tier hit: spilled KV returns by one host-to-device
            # scatter — the prefill those pages held is skipped again
            with self.tracer.span("restore_pages", pages=len(restored)):
                self.state = self.engine.restore_pages(
                    self.state, [p for p, _ in restored],
                    [ent["kv"] for _, ent in restored])
            self.stats["host_hits"] += 1
            self.stats["host_restored_pages"] += len(restored)
            self.stats["host_hit_tokens"] += \
                len(restored) * self.engine.page_size
        if cow_pairs:
            self.state = self.engine.copy_pages(
                self.state, [s for s, _ in cow_pairs],
                [d for _, d in cow_pairs])
            self.stats["cow_pages"] += len(cow_pairs)
            self.tracer.instant("cow", pages=len(cow_pairs))
        self.stats["prefix_lookups"] += 1
        if plan.shared or plan.host_keys:
            self.stats["prefix_hits"] += 1
            self.stats["prefix_hit_tokens"] += plan.resume
        if plan.snap_key is not None:
            self.state = self.engine.set_slot_state(
                self.state, slot, self._pages.snapshot(plan.snap_key))

    def _set_sampling(self, r: Request, slot: int) -> None:
        """Install ``r``'s sampling config into ``slot`` before its first
        prefill.  Greedy requests entering a slot that is still greedy
        skip the host-side write — the engine's default rows already
        encode the argmax path, which keeps pure-greedy serving on
        exactly the pre-sampling admission sequence."""
        sp = r.sampling
        if sp.greedy and slot not in self._sampled_slots:
            return
        self.state = self.engine.set_sampling(self.state, slot, sp,
                                              np.asarray(r.prompt, np.int32))
        if sp.greedy:
            self._sampled_slots.discard(slot)
        else:
            self._sampled_slots.add(slot)

    def _defer(self, r: Request) -> None:
        self.tracer.instant("defer", rid=r.rid)
        self.stats["deferred_admissions"] += 1
        n = self._defer_counts.get(r.rid, 0) + 1
        self._defer_counts[r.rid] = n
        self.stats["max_defer_cycles"] = max(
            self.stats["max_defer_cycles"], n)

    def _note_first(self, r: Request) -> None:
        if r.rid not in self.ttft:
            # ONE clock read feeds both the legacy ttft value and the
            # prefill->decode span boundary, so span-derived TTFT equals
            # this dict to float precision (acceptance bound: 1 ms)
            now = time.perf_counter()
            self.ttft[r.rid] = now - self._run_t0
            self._req_phase(r.rid, "decode", at=now)

    # -- per-request lifecycle spans ----------------------------------------
    def _req_phase(self, rid: int, name: str,
                   at: Optional[float] = None) -> None:
        """Close ``rid``'s current lifecycle span (if any) and open
        ``name`` back-to-back at the same timestamp, on the request's own
        ``rid<N>`` trace track — so each track is a gapless sequence of
        queued/prefill/decode/preempted spans."""
        if at is None:
            at = time.perf_counter()
        h = self._rid_open.pop(rid, None)
        if h is not None:
            self.tracer.end(h, at=at)
        self._rid_open[rid] = self.tracer.begin(name, tid=f"rid{rid}",
                                                at=at, rid=rid)

    def _req_end(self, r: Request) -> None:
        """Close ``r``'s lifecycle track with a ``finish`` instant."""
        now = time.perf_counter()
        h = self._rid_open.pop(r.rid, None)
        if h is not None:
            self.tracer.end(h, at=now)
        self.tracer.instant("finish", tid=f"rid{r.rid}", at=now, rid=r.rid,
                            tokens=len(r.generated))

    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def _preempt_one(self, active: Dict[int, Request], free: deque,
                     swapped: "deque[_Swapped]") -> None:
        """Swap out the most recently admitted active slot: device_get of
        just its pool rows + recurrent rows, free its pages and slot, park
        the request on the restore queue.  ALL its pages travel in the
        blob — shared ones included, since their cached copies may be
        reclaimed before the restore — and the restore claims all-fresh
        pages, so a swapped request never depends on cache residency."""
        slot = max(active, key=lambda s: self._admit_seq.get(s, 0))
        r = active.pop(slot)
        pages = self._pages.table(slot)
        with self.tracer.span("swap_out", rid=r.rid, pages=len(pages)):
            blob = self.engine.swap_out(self.state, slot, pages)
            self._pages.free(slot)
            self.state = self.engine.release_pages(self.state, slot)
        free.append(slot)
        if self.drafter is not None:
            self.drafter.release(slot)
        swapped.append(_Swapped(r, blob, len(pages)))
        self.stats["preemptions"] += 1
        self._req_phase(r.rid, "preempted")

    def _evict(self, slot: int, free: deque) -> None:
        free.append(slot)
        if self.engine.paged:
            self._pages.free(slot)
            # clear the slot's page row: the freed pages may be reassigned
            # immediately, and a stale row would let any later unmasked
            # write through this slot land in the new owner's pages
            self.state = self.engine.release_pages(self.state, slot)
        if self.drafter is not None:
            self.drafter.release(slot)

    def _chunkable(self, r: Request, chunk: int) -> bool:
        # VLM prompts prefill whole: the image patches and prompt tokens
        # embed as one stream, and patches dominate the prefix anyway
        return chunk > 0 and "patches" not in r.extras \
            and len(np.asarray(r.prompt)) > chunk

    def _admit(self, r: Request, slot: int) -> None:
        """Whole-prompt prefill-insert of ``r`` into ``slot``."""
        prompt = np.asarray(r.prompt, np.int32)
        inputs = {"tokens": prompt[None, :]}
        for k, v in r.extras.items():
            inputs[k] = np.asarray(v)[None]
        t0 = time.perf_counter()
        h = self.tracer.begin("prefill_insert", at=t0, rid=r.rid)
        self.state, tok = self.engine.insert(self.state, inputs, slot)
        first = int(np.asarray(tok)[0])     # sync point ends the timing
        now = time.perf_counter()
        self.tracer.end(h, at=now)
        self.stats["prefill_s"] += now - t0
        self.stats["prefill_tokens"] += sum(
            int(np.shape(v)[1]) for v in inputs.values())
        r.generated.append(first)
        r.slot = slot
        self.slot_history[slot].append(r.rid)
        self.admission_order.append(r.rid)
        self._note_first(r)

    def _prefill_one_chunk(self, adm: _Admission) -> bool:
        """Insert the next chunk of ``adm``; True once the prompt is done.

        ``capture`` admissions clip every chunk to the next page boundary
        and snapshot the slot's recurrent state there, so each registered
        prefix page carries the state a future admission resumes from."""
        r = adm.r
        prompt = np.asarray(r.prompt, np.int32)
        remaining = len(prompt) - adm.cursor
        c = self.engine.prefill_chunk or remaining
        if adm.capture:
            ps = self.engine.page_size
            c = min(c, ps - adm.cursor % ps)
        c = min(c, remaining)
        toks = prompt[None, adm.cursor:adm.cursor + c]
        t0 = time.perf_counter()
        h = self.tracer.begin("prefill_chunk", at=t0, rid=r.rid,
                              cursor=adm.cursor, tokens=int(c))
        self.state, tok = self.engine.insert_chunk(
            self.state, {"tokens": toks}, adm.slot, adm.cursor)
        first = int(np.asarray(tok)[0])     # sync point ends the timing
        now = time.perf_counter()
        self.tracer.end(h, at=now)
        self.stats["prefill_s"] += now - t0
        self.stats["prefill_tokens"] += c
        self.stats["prefill_chunks"] += 1
        adm.cursor += c
        if adm.capture and adm.cursor % self.engine.page_size == 0:
            adm.snaps[adm.cursor // self.engine.page_size] = \
                self.engine.get_slot_state(self.state, adm.slot)
        if self.prefix_cache and "patches" not in r.extras:
            # in-flight registration: every completed page becomes
            # matchable the moment its last token lands, so a concurrent
            # admission sharing this prompt's prefix rides the LIVE
            # slot's pages (refcount bump, CoW on divergence) instead of
            # waiting for the whole prefill to finish
            self._pages.register(adm.slot, prompt,
                                 snaps=adm.snaps if adm.capture else None,
                                 up_to=adm.cursor)
        if adm.cursor < len(prompt):
            return False
        r.generated.append(first)           # final chunk's emitted token
        r.slot = adm.slot
        self.slot_history[adm.slot].append(r.rid)
        self.admission_order.append(r.rid)
        self._note_first(r)
        return True

    # -- speculation -------------------------------------------------------
    def _spec_round(self, active: Dict[int, Request], mask: np.ndarray):
        """One speculative decode round: draft for every active slot with
        budget headroom, verify all drafts in one fused forward.  Returns
        (emitted (slots, >=1) greedy tokens, consumed (slots,)); falls
        back to the plain fused decode when nothing was drafted (so an
        empty-handed drafter costs a (slots, K+1)-shaped forward nothing).
        """
        S, K = self.engine.slots, self.spec_k
        drafts = np.zeros((S, K), np.int32)
        dlen = np.zeros((S,), np.int32)
        wants = {}
        for slot, r in active.items():
            # cap so consumed <= remaining budget: the verify step advances
            # the slot by every accepted token, and acceptance beyond the
            # budget could not be rolled back host-side
            k_s = min(K, r.max_new - len(r.generated) - 1)
            if k_s > 0:
                wants[slot] = (np.concatenate(
                    [np.asarray(r.prompt, np.int32),
                     np.asarray(r.generated, np.int32)]), k_s)
        proposals = {}
        if wants:
            with self.tracer.span("spec_propose", slots=len(wants)):
                proposals = self.drafter.propose(wants)
        for slot, d in proposals.items():
            d = np.asarray(d, np.int32).ravel()[:wants[slot][1]]
            drafts[slot, :len(d)] = d
            dlen[slot] = len(d)
        self.stats["spec_proposed"] += int(dlen.sum())
        if not dlen.any():
            self.state, toks = self.engine.decode(self.state, active=mask)
            return np.asarray(toks)[:, None], mask.astype(np.int32)
        with self.tracer.span("spec_verify", drafted=int(dlen.sum())):
            self.state, emitted, consumed = self.engine.verify(
                self.state, drafts, dlen, mask)
            emitted, consumed = np.asarray(emitted), np.asarray(consumed)
        self.stats["spec_steps"] += 1
        self.stats["spec_accepted"] += int(consumed[mask].sum() - mask.sum())
        return emitted, consumed

    # -- the serving loop --------------------------------------------------
    def run(self, requests: Sequence[Request]) -> Dict[int, List[int]]:
        """Serve ``requests`` to completion; returns {rid: generated}.

        ``stats`` describes this run alone (reset here); totals across
        runs accumulate in ``lifetime_stats``."""
        self.stats.reset()
        self.decode_gaps.reset()
        self._last_decode_t = None
        self.ttft.clear()
        self._run_t0 = time.perf_counter()
        self._defer_counts = {}
        self._admit_seq = {}
        self._seq = 0
        self.admission_order = []
        self._rid_open = {}
        h_run = self.tracer.begin("run", at=self._run_t0,
                                  requests=len(requests))
        try:
            return self._run(requests)
        finally:
            for h in self._rid_open.values():   # aborted-run lifecycles
                self.tracer.end(h)
            self._rid_open.clear()
            self.tracer.end(h_run)
            self._drain_pool_events()
            self._fold_lifetime()

    def _run(self, requests: Sequence[Request]) -> Dict[int, List[int]]:
        for r in requests:
            # fail fast on the whole queue (host-side and cheap): an
            # unservable request deep in the queue must not discard the
            # tokens already generated for the requests ahead of it
            self._validate(r)
        for r in requests:
            # every request's lifecycle track starts queued at run start
            # (the arrival model run() exposes — a whole queue at once)
            self._req_phase(r.rid, "queued", at=self._run_t0)
        pending = deque(requests)
        active: Dict[int, Request] = {}
        admissions: deque[_Admission] = deque()
        swapped: deque[_Swapped] = deque()
        free = deque(range(self.engine.slots))
        chunk = self.engine.prefill_chunk if self.engine.paged else 0
        while pending or active or admissions or swapped:
            # one "iter" span per loop pass: with the nested phase spans it
            # accounts for effectively all wall-clock between the first
            # admission and the last finish (the >= 95% coverage gate)
            h_it = self.tracer.begin("iter")
            progressed = False
            # restore preempted requests first (their pages and slot were
            # taken to absorb a burst — they are owed the next headroom);
            # a restore claims all-fresh pages and never preempts, so a
            # preempt/restore pair can never livelock
            while swapped:
                sw = swapped[0]
                if not free or self._pages.available() < sw.n_pages:
                    # the head keeps waiting — record the cycle so the
                    # wait shows up in deferred_admissions either way
                    self._defer(sw.r)
                    break
                swapped.popleft()
                slot = free.popleft()
                with self.tracer.span("swap_in", rid=sw.r.rid,
                                      pages=sw.n_pages):
                    pages = self._pages.alloc(slot, sw.n_pages)
                    self.state = self.engine.swap_in(self.state, slot,
                                                     pages, sw.blob)
                self._req_phase(sw.r.rid, "decode")
                if sw.r.sampling.greedy:
                    self._sampled_slots.discard(slot)
                else:
                    self._sampled_slots.add(slot)
                self._admit_seq[slot] = self._next_seq()
                sw.r.slot = slot
                self.slot_history[slot].append(sw.r.rid)
                self.admission_order.append(sw.r.rid)
                active[slot] = sw.r
                self.stats["restores"] += 1
                progressed = True
            # pages the parked restore head is owed: pending admissions
            # below must fit WITHOUT them, or the very pages the head
            # waits for get claimed out from under it cycle after cycle
            # (a small-request flood would starve a large restore forever)
            reserve = swapped[0].n_pages if swapped else 0
            # admit pending requests into free slots (claiming pages first
            # in paged mode — a short free list defers admission until an
            # eviction returns pages, unless preemption can take them from
            # the most recently admitted active slot)
            while pending and free:
                r = pending[0]
                h_adm = self.tracer.begin("admit", rid=r.rid)
                plan = self._plan(r) if self.engine.paged else None
                if self.engine.paged and not self._fits(plan, reserve):
                    while self.preempt and active and \
                            not self._fits(plan, reserve) and \
                            plan.fresh_needed + reserve <= \
                            self._pages.reclaimable(plan.shared) + \
                            self._preempt_gain(active, plan):
                        self._preempt_one(active, free, swapped)
                        progressed = True
                    # a matched run can make a plan UNFITTABLE on a tight
                    # pool (the shared pages are pinned, and CoW + host
                    # restores each cost a fresh page) even when a plain
                    # cold admission would fit — degrade the plan page by
                    # page (host tail drops first) down to cold before
                    # giving up, or a queue with nothing in flight would
                    # deadlock on a hit it cannot afford
                    while not self._fits(plan, reserve) and \
                            (plan.shared or plan.host_keys):
                        plan = self._plan(r, max_run=len(plan.shared) +
                                          len(plan.host_keys) - 1)
                    if not self._fits(plan, reserve):
                        self.tracer.end(h_adm, deferred=True)
                        self._defer(r)
                        break
                pending.popleft()
                slot = free.popleft()
                self._admit_seq[slot] = self._next_seq()
                self._req_phase(r.rid, "prefill")
                if self.engine.paged:
                    self._claim_pages(r, slot, plan)
                self._set_sampling(r, slot)
                resume = plan.resume if plan is not None else 0
                capture = self.prefix_cache \
                    and self.engine.has_recurrent_state \
                    and "patches" not in r.extras
                if resume > 0 or self._chunkable(r, chunk) or \
                        (capture and len(np.asarray(r.prompt))
                         >= self.engine.page_size):
                    admissions.append(_Admission(r, slot, cursor=resume,
                                                 capture=capture))
                    progressed = True
                else:
                    self._admit(r, slot)
                    progressed = True
                    if self.prefix_cache and not capture \
                            and "patches" not in r.extras:
                        self._pages.register(
                            slot, np.asarray(r.prompt, np.int32))
                    if self._done(r):       # EOS straight out of prefill
                        self._req_end(r)
                        self._evict(slot, free)
                    else:
                        active[slot] = r
                self.tracer.end(h_adm, slot=slot)
            # one prefill chunk of the admission at the head of the queue,
            # then fall through to the all-slot decode: long-prompt
            # admission interleaves with in-flight decodes
            if admissions:
                adm = admissions[0]
                progressed = True
                if self._prefill_one_chunk(adm):
                    admissions.popleft()
                    if self._done(adm.r):
                        self._req_end(adm.r)
                        self._evict(adm.slot, free)
                    else:
                        active[adm.slot] = adm.r
            if active:
                progressed = True
                mask = None
                if self.engine.paged:
                    mask = np.zeros((self.engine.slots,), bool)
                    mask[list(active)] = True
                t0 = time.perf_counter()
                h_dec = self.tracer.begin("decode_step", at=t0,
                                          slots=len(active))
                if self.spec_k:
                    emitted, consumed = self._spec_round(active, mask)
                else:
                    self.state, toks = self.engine.decode(self.state,
                                                          active=mask)
                    emitted = np.asarray(toks)[:, None]
                    consumed = np.ones((self.engine.slots,), np.int32)
                now = time.perf_counter()   # emitted is host -> synced
                self.tracer.end(h_dec, at=now)
                self.stats["decode_s"] += now - t0
                self.stats["decode_steps"] += 1
                self.stats["decode_slot_steps"] += len(active)
                if self._last_decode_t is not None:
                    gap = now - self._last_decode_t
                    self.stats["max_decode_gap_s"] = max(
                        self.stats["max_decode_gap_s"], gap)
                    self.decode_gaps.record(gap)
                self._last_decode_t = now
                for slot, r in list(active.items()):
                    # a spec round can emit several tokens; honor EOS as
                    # soon as it lands (the slot's cache advanced past it,
                    # but a finished request's slot is evicted anyway)
                    for tok in emitted[slot, :consumed[slot]]:
                        r.generated.append(int(tok))
                        self.stats["decode_tokens"] += 1
                        if self._done(r):
                            break
                    if self._done(r):
                        del active[slot]
                        self._req_end(r)
                        self._evict(slot, free)
                if not active:
                    self._last_decode_t = None
            self.tracer.end(h_it)
            if not progressed:
                # nothing in flight can ever free the pages the head
                # request needs — admission would spin forever
                raise RuntimeError(
                    "admission deadlock: pending/swapped requests but no "
                    "free slot/pages and nothing in flight to evict")
        return {r.rid: list(r.generated) for r in requests}
