"""Continuous batching over the inference engine's request slots.

The scheduler owns the batching POLICY the engine deliberately excludes:
admit a pending request into any free slot, run the fused all-slot decode
step, harvest each active slot's token, and evict a slot the moment its
request finishes — EOS token or per-request ``max_new`` budget — so the
next pending request reuses it without reshaping the state.

Paged engines add two policy layers:

  * a host-side free list of physical pages — admission also claims
    ``ceil((patches + prompt + max_new) / page_size)`` pages for the slot
    (installed via ``engine.assign_pages``) and eviction returns them, so
    KV memory follows live tokens, not ``slots * max_len``;
  * an ADMISSION QUEUE for chunked prefill: a long prompt is inserted
    ``engine.prefill_chunk`` tokens at a time, one chunk per scheduler
    iteration, alternating with the fused all-slot decode step — admitting
    a long request no longer stalls in-flight decodes for the whole
    prompt's prefill.  ``stats["max_decode_gap_s"]`` records the worst
    stall in-flight decodes actually experienced.

Each slot's computation is independent of its neighbours (attention,
recurrent state and MoE routing are all per-row), so a request's greedy
output is a function of its prompt alone: deterministic under any
arrival order, slot assignment, co-batched traffic, or prefill chunking
— the property ``tests/test_serve.py`` pins.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serve.engine import InferenceEngine
from repro.serve.state import InferenceState


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                      # (L,) int32 token ids
    max_new: int = 16
    extras: Dict[str, np.ndarray] = field(default_factory=dict)  # e.g. patches
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None              # last slot served in (telemetry)


@dataclass
class _Admission:
    """A request whose prompt is being chunk-prefilled into its slot."""
    r: Request
    slot: int
    cursor: int = 0                         # prompt tokens inserted so far


class Scheduler:
    """Drives an :class:`InferenceEngine` over a queue of requests."""

    def __init__(self, engine: InferenceEngine, state: InferenceState, *,
                 eos_id: Optional[int] = None):
        self.engine = engine
        self.state = state
        self.eos_id = eos_id
        #: per-slot rid history — lets tests assert slots are actually reused
        self.slot_history: Dict[int, List[int]] = {
            s: [] for s in range(engine.slots)}
        self.stats = {"prefill_tokens": 0, "prefill_s": 0.0,
                      "prefill_chunks": 0,
                      "decode_tokens": 0, "decode_s": 0.0, "decode_steps": 0,
                      "max_decode_gap_s": 0.0}
        self._free_pages = deque(range(engine.num_pages)) \
            if engine.paged else None
        self._slot_pages: Dict[int, list] = {}
        self._last_decode_t: Optional[float] = None

    def _done(self, r: Request) -> bool:
        if not r.generated:
            return False
        if self.eos_id is not None and r.generated[-1] == self.eos_id:
            return True
        return len(r.generated) >= r.max_new

    # -- admission ---------------------------------------------------------
    def _total_len(self, r: Request) -> int:
        patches = int(np.shape(r.extras["patches"])[0]) \
            if "patches" in r.extras else 0
        return patches + len(np.asarray(r.prompt)) + r.max_new

    def _pages_needed(self, r: Request) -> int:
        return -(-self._total_len(r) // self.engine.page_size)

    def _validate(self, r: Request) -> None:
        if r.max_new < 1:
            # the prefill itself emits the first greedy token, so a budget
            # below one token is unservable rather than silently exceeded
            raise ValueError(f"request {r.rid}: max_new must be >= 1")
        total = self._total_len(r)
        if total > self.engine.max_len:
            raise ValueError(
                f"request {r.rid}: patches + prompt + max_new = {total} "
                f"exceeds engine max_len {self.engine.max_len} (the cache "
                f"would wrap and overwrite live context)")
        if self.engine.paged and self._pages_needed(r) > self.engine.num_pages:
            raise ValueError(
                f"request {r.rid}: needs {self._pages_needed(r)} pages but "
                f"the pool only has {self.engine.num_pages}")

    def _alloc_pages(self, r: Request, slot: int) -> None:
        pages = [self._free_pages.popleft()
                 for _ in range(self._pages_needed(r))]
        self._slot_pages[slot] = pages
        self.state = self.engine.assign_pages(self.state, slot, pages)

    def _evict(self, slot: int, free: deque) -> None:
        free.append(slot)
        if self.engine.paged:
            self._free_pages.extend(self._slot_pages.pop(slot))
            # clear the slot's page row: the freed pages may be reassigned
            # immediately, and a stale row would let any later unmasked
            # write through this slot land in the new owner's pages
            self.state = self.engine.release_pages(self.state, slot)

    def _chunkable(self, r: Request, chunk: int) -> bool:
        # VLM prompts prefill whole: the image patches and prompt tokens
        # embed as one stream, and patches dominate the prefix anyway
        return chunk > 0 and "patches" not in r.extras \
            and len(np.asarray(r.prompt)) > chunk

    def _admit(self, r: Request, slot: int) -> None:
        """Whole-prompt prefill-insert of ``r`` into ``slot``."""
        prompt = np.asarray(r.prompt, np.int32)
        inputs = {"tokens": prompt[None, :]}
        for k, v in r.extras.items():
            inputs[k] = np.asarray(v)[None]
        t0 = time.perf_counter()
        self.state, tok = self.engine.insert(self.state, inputs, slot)
        first = int(np.asarray(tok)[0])     # sync point ends the timing
        self.stats["prefill_s"] += time.perf_counter() - t0
        self.stats["prefill_tokens"] += sum(
            int(np.shape(v)[1]) for v in inputs.values())
        r.generated.append(first)
        r.slot = slot
        self.slot_history[slot].append(r.rid)

    def _prefill_one_chunk(self, adm: _Admission) -> bool:
        """Insert the next chunk of ``adm``; True once the prompt is done."""
        r = adm.r
        prompt = np.asarray(r.prompt, np.int32)
        c = min(self.engine.prefill_chunk, len(prompt) - adm.cursor)
        toks = prompt[None, adm.cursor:adm.cursor + c]
        t0 = time.perf_counter()
        self.state, tok = self.engine.insert_chunk(
            self.state, {"tokens": toks}, adm.slot, adm.cursor)
        first = int(np.asarray(tok)[0])     # sync point ends the timing
        self.stats["prefill_s"] += time.perf_counter() - t0
        self.stats["prefill_tokens"] += c
        self.stats["prefill_chunks"] += 1
        adm.cursor += c
        if adm.cursor < len(prompt):
            return False
        r.generated.append(first)           # final chunk's greedy token
        r.slot = adm.slot
        self.slot_history[adm.slot].append(r.rid)
        return True

    # -- the serving loop --------------------------------------------------
    def run(self, requests: Sequence[Request]) -> Dict[int, List[int]]:
        """Serve ``requests`` to completion; returns {rid: generated}."""
        for r in requests:
            # fail fast on the whole queue (host-side and cheap): an
            # unservable request deep in the queue must not discard the
            # tokens already generated for the requests ahead of it
            self._validate(r)
        pending = deque(requests)
        active: Dict[int, Request] = {}
        admissions: deque[_Admission] = deque()
        free = deque(range(self.engine.slots))
        chunk = self.engine.prefill_chunk if self.engine.paged else 0
        while pending or active or admissions:
            progressed = False
            # admit pending requests into free slots (claiming pages first
            # in paged mode — a short free list defers admission until an
            # eviction returns pages)
            while pending and free:
                r = pending[0]
                if self.engine.paged and \
                        len(self._free_pages) < self._pages_needed(r):
                    break
                pending.popleft()
                slot = free.popleft()
                if self.engine.paged:
                    self._alloc_pages(r, slot)
                if self._chunkable(r, chunk):
                    admissions.append(_Admission(r, slot))
                    progressed = True
                else:
                    self._admit(r, slot)
                    progressed = True
                    if self._done(r):       # EOS straight out of prefill
                        self._evict(slot, free)
                    else:
                        active[slot] = r
            # one prefill chunk of the admission at the head of the queue,
            # then fall through to the all-slot decode: long-prompt
            # admission interleaves with in-flight decodes
            if admissions:
                adm = admissions[0]
                progressed = True
                if self._prefill_one_chunk(adm):
                    admissions.popleft()
                    if self._done(adm.r):
                        self._evict(adm.slot, free)
                    else:
                        active[adm.slot] = adm.r
            if active:
                progressed = True
                mask = None
                if self.engine.paged:
                    mask = np.zeros((self.engine.slots,), bool)
                    mask[list(active)] = True
                t0 = time.perf_counter()
                self.state, toks = self.engine.decode(self.state,
                                                      active=mask)
                toks = np.asarray(toks)     # sync point ends the timing
                now = time.perf_counter()
                self.stats["decode_s"] += now - t0
                self.stats["decode_steps"] += 1
                self.stats["decode_tokens"] += len(active)
                if self._last_decode_t is not None:
                    self.stats["max_decode_gap_s"] = max(
                        self.stats["max_decode_gap_s"],
                        now - self._last_decode_t)
                self._last_decode_t = now
                for slot, r in list(active.items()):
                    r.generated.append(int(toks[slot]))
                    if self._done(r):
                        del active[slot]
                        self._evict(slot, free)
                if not active:
                    self._last_decode_t = None
            if not progressed:
                # nothing in flight can ever free the pages the head
                # request needs — admission would spin forever
                raise RuntimeError(
                    "admission deadlock: pending requests but no free "
                    "slot/pages and nothing in flight to evict")
        return {r.rid: list(r.generated) for r in requests}
