"""Continuous batching over the inference engine's request slots.

The scheduler owns the batching POLICY the engine deliberately excludes:
admit a pending request into any free slot, run the fused all-slot decode
step, harvest each active slot's token, and evict a slot the moment its
request finishes — EOS token or per-request ``max_new`` budget — so the
next pending request reuses it without reshaping the state.

Paged engines add two policy layers:

  * a host-side free list of physical pages — admission also claims
    ``ceil((patches + prompt + max_new) / page_size)`` pages for the slot
    (installed via ``engine.assign_pages``) and eviction returns them, so
    KV memory follows live tokens, not ``slots * max_len``;
  * an ADMISSION QUEUE for chunked prefill: a long prompt is inserted
    ``engine.prefill_chunk`` tokens at a time, one chunk per scheduler
    iteration, alternating with the fused all-slot decode step — admitting
    a long request no longer stalls in-flight decodes for the whole
    prompt's prefill.  ``stats["max_decode_gap_s"]`` records the worst
    stall in-flight decodes actually experienced.

SPECULATIVE DECODING (``spec_k > 0``, paged engines): instead of one
token per fused step, each active slot asks a :class:`~repro.serve.
speculative.Drafter` for up to ``spec_k`` guessed next tokens and the
engine checks every guess in ONE ``verify`` forward, accepting the
longest greedy-matching prefix (plus the model's own next token).  The
serve path is greedy end to end, so speculation is lossless — emitted
streams are bit-identical to the ``spec_k == 0`` baseline; acceptance
only changes how many tokens a step yields (``stats["spec_*"]``).

Each slot's computation is independent of its neighbours (attention,
recurrent state and MoE routing are all per-row), so a request's greedy
output is a function of its prompt alone: deterministic under any
arrival order, slot assignment, co-batched traffic, prefill chunking,
or speculation depth — the property ``tests/test_serve.py`` and
``tests/test_serve_speculative.py`` pin.

``stats`` counts ONE call to :meth:`Scheduler.run`: it resets when a run
starts (a second batch is never polluted by the first's throughput or
``max_decode_gap_s``); ``lifetime_stats`` accumulates across runs.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serve.engine import InferenceEngine
from repro.serve.state import InferenceState


class PagePool:
    """Host-side free list of physical KV pages with conservation checking.

    Every admission (``alloc``) and eviction (``free``) moves pages
    between the free list and a per-slot ownership map, and every
    operation re-checks the invariant the hypothesis property test in
    ``tests/test_property.py`` drives: pages are never leaked, never
    double-owned, and ``available() + pages_in_tables() == num_pages``
    at all times.  Misuse fails loudly — ``alloc`` of an occupied slot
    or beyond capacity raises, ``free`` of an unowned slot raises."""

    def __init__(self, num_pages: int):
        self.num_pages = int(num_pages)
        self._free: deque = deque(range(self.num_pages))
        self._owned: Dict[int, List[int]] = {}

    def available(self) -> int:
        return len(self._free)

    def pages_in_tables(self) -> int:
        return sum(len(p) for p in self._owned.values())

    def owner_slots(self):
        return set(self._owned)

    def alloc(self, slot: int, n: int) -> List[int]:
        if slot in self._owned:
            raise ValueError(f"slot {slot} already owns pages "
                             f"{self._owned[slot]} (double admission)")
        if n < 1:
            raise ValueError(f"slot {slot}: cannot allocate {n} pages")
        if n > len(self._free):
            raise ValueError(f"slot {slot}: wants {n} pages, only "
                             f"{len(self._free)} free (defer admission)")
        pages = [self._free.popleft() for _ in range(n)]
        self._owned[slot] = pages
        self._check()
        return pages

    def free(self, slot: int) -> List[int]:
        if slot not in self._owned:
            raise KeyError(f"slot {slot} owns no pages (double free?)")
        pages = self._owned.pop(slot)
        self._free.extend(pages)
        self._check()
        return pages

    def _check(self) -> None:
        seen = list(self._free) + [p for ps in self._owned.values()
                                   for p in ps]
        assert len(seen) == len(set(seen)) == self.num_pages, \
            f"page conservation broken: {len(set(seen))} distinct of " \
            f"{len(seen)} tracked vs {self.num_pages} total"


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                      # (L,) int32 token ids
    max_new: int = 16
    extras: Dict[str, np.ndarray] = field(default_factory=dict)  # e.g. patches
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None              # last slot served in (telemetry)


@dataclass
class _Admission:
    """A request whose prompt is being chunk-prefilled into its slot."""
    r: Request
    slot: int
    cursor: int = 0                         # prompt tokens inserted so far


class Scheduler:
    """Drives an :class:`InferenceEngine` over a queue of requests."""

    def __init__(self, engine: InferenceEngine, state: InferenceState, *,
                 eos_id: Optional[int] = None, spec_k: int = 0,
                 drafter=None):
        self.engine = engine
        self.state = state
        self.eos_id = eos_id
        self.spec_k = int(spec_k)
        if self.spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        if self.spec_k and not engine.paged:
            raise ValueError("speculative decoding runs over the paged KV "
                             "pool; spec_k > 0 requires paged=True "
                             "(spec_k=0 is the parity baseline)")
        if self.spec_k and drafter is None:
            from repro.serve.speculative import NgramDrafter
            drafter = NgramDrafter()
        self.drafter = drafter
        #: per-slot rid history — lets tests assert slots are actually reused
        self.slot_history: Dict[int, List[int]] = {
            s: [] for s in range(engine.slots)}
        self.stats = self._fresh_stats()
        #: accumulated across every finished/aborted run() on this scheduler
        self.lifetime_stats = self._fresh_stats()
        self._pages = PagePool(engine.num_pages) if engine.paged else None
        self._last_decode_t: Optional[float] = None

    @staticmethod
    def _fresh_stats() -> Dict[str, float]:
        return {"prefill_tokens": 0, "prefill_s": 0.0, "prefill_chunks": 0,
                "decode_tokens": 0, "decode_s": 0.0, "decode_steps": 0,
                # slot-steps: sum over fused rounds of |active slots| — the
                # denominator for accepted-tokens-per-step (== decode_tokens
                # without speculation; smaller when drafts are accepted)
                "decode_slot_steps": 0,
                "max_decode_gap_s": 0.0,
                # speculative counters: proposed drafts, drafts accepted,
                # verify rounds (a subset of decode_steps)
                "spec_proposed": 0, "spec_accepted": 0, "spec_steps": 0}

    def _fold_lifetime(self) -> None:
        for k, v in self.stats.items():
            if k == "max_decode_gap_s":     # a max, not a sum
                self.lifetime_stats[k] = max(self.lifetime_stats[k], v)
            else:
                self.lifetime_stats[k] += v

    def _done(self, r: Request) -> bool:
        if not r.generated:
            return False
        if self.eos_id is not None and r.generated[-1] == self.eos_id:
            return True
        return len(r.generated) >= r.max_new

    # -- admission ---------------------------------------------------------
    def _total_len(self, r: Request) -> int:
        patches = int(np.shape(r.extras["patches"])[0]) \
            if "patches" in r.extras else 0
        return patches + len(np.asarray(r.prompt)) + r.max_new

    def _pages_needed(self, r: Request) -> int:
        return -(-self._total_len(r) // self.engine.page_size)

    def _validate(self, r: Request) -> None:
        if r.max_new < 1:
            # the prefill itself emits the first greedy token, so a budget
            # below one token is unservable rather than silently exceeded
            raise ValueError(f"request {r.rid}: max_new must be >= 1")
        total = self._total_len(r)
        if total > self.engine.max_len:
            raise ValueError(
                f"request {r.rid}: patches + prompt + max_new = {total} "
                f"exceeds engine max_len {self.engine.max_len} (the cache "
                f"would wrap and overwrite live context)")
        if self.engine.paged and self._pages_needed(r) > self.engine.num_pages:
            raise ValueError(
                f"request {r.rid}: needs {self._pages_needed(r)} pages but "
                f"the pool only has {self.engine.num_pages}")

    def _alloc_pages(self, r: Request, slot: int) -> None:
        pages = self._pages.alloc(slot, self._pages_needed(r))
        self.state = self.engine.assign_pages(self.state, slot, pages)

    def _evict(self, slot: int, free: deque) -> None:
        free.append(slot)
        if self.engine.paged:
            self._pages.free(slot)
            # clear the slot's page row: the freed pages may be reassigned
            # immediately, and a stale row would let any later unmasked
            # write through this slot land in the new owner's pages
            self.state = self.engine.release_pages(self.state, slot)
        if self.drafter is not None:
            self.drafter.release(slot)

    def _chunkable(self, r: Request, chunk: int) -> bool:
        # VLM prompts prefill whole: the image patches and prompt tokens
        # embed as one stream, and patches dominate the prefix anyway
        return chunk > 0 and "patches" not in r.extras \
            and len(np.asarray(r.prompt)) > chunk

    def _admit(self, r: Request, slot: int) -> None:
        """Whole-prompt prefill-insert of ``r`` into ``slot``."""
        prompt = np.asarray(r.prompt, np.int32)
        inputs = {"tokens": prompt[None, :]}
        for k, v in r.extras.items():
            inputs[k] = np.asarray(v)[None]
        t0 = time.perf_counter()
        self.state, tok = self.engine.insert(self.state, inputs, slot)
        first = int(np.asarray(tok)[0])     # sync point ends the timing
        self.stats["prefill_s"] += time.perf_counter() - t0
        self.stats["prefill_tokens"] += sum(
            int(np.shape(v)[1]) for v in inputs.values())
        r.generated.append(first)
        r.slot = slot
        self.slot_history[slot].append(r.rid)

    def _prefill_one_chunk(self, adm: _Admission) -> bool:
        """Insert the next chunk of ``adm``; True once the prompt is done."""
        r = adm.r
        prompt = np.asarray(r.prompt, np.int32)
        c = min(self.engine.prefill_chunk, len(prompt) - adm.cursor)
        toks = prompt[None, adm.cursor:adm.cursor + c]
        t0 = time.perf_counter()
        self.state, tok = self.engine.insert_chunk(
            self.state, {"tokens": toks}, adm.slot, adm.cursor)
        first = int(np.asarray(tok)[0])     # sync point ends the timing
        self.stats["prefill_s"] += time.perf_counter() - t0
        self.stats["prefill_tokens"] += c
        self.stats["prefill_chunks"] += 1
        adm.cursor += c
        if adm.cursor < len(prompt):
            return False
        r.generated.append(first)           # final chunk's greedy token
        r.slot = adm.slot
        self.slot_history[adm.slot].append(r.rid)
        return True

    # -- speculation -------------------------------------------------------
    def _spec_round(self, active: Dict[int, Request], mask: np.ndarray):
        """One speculative decode round: draft for every active slot with
        budget headroom, verify all drafts in one fused forward.  Returns
        (emitted (slots, >=1) greedy tokens, consumed (slots,)); falls
        back to the plain fused decode when nothing was drafted (so an
        empty-handed drafter costs a (slots, K+1)-shaped forward nothing).
        """
        S, K = self.engine.slots, self.spec_k
        drafts = np.zeros((S, K), np.int32)
        dlen = np.zeros((S,), np.int32)
        wants = {}
        for slot, r in active.items():
            # cap so consumed <= remaining budget: the verify step advances
            # the slot by every accepted token, and acceptance beyond the
            # budget could not be rolled back host-side
            k_s = min(K, r.max_new - len(r.generated) - 1)
            if k_s > 0:
                wants[slot] = (np.concatenate(
                    [np.asarray(r.prompt, np.int32),
                     np.asarray(r.generated, np.int32)]), k_s)
        proposals = self.drafter.propose(wants) if wants else {}
        for slot, d in proposals.items():
            d = np.asarray(d, np.int32).ravel()[:wants[slot][1]]
            drafts[slot, :len(d)] = d
            dlen[slot] = len(d)
        self.stats["spec_proposed"] += int(dlen.sum())
        if not dlen.any():
            self.state, toks = self.engine.decode(self.state, active=mask)
            return np.asarray(toks)[:, None], mask.astype(np.int32)
        self.state, emitted, consumed = self.engine.verify(
            self.state, drafts, dlen, mask)
        emitted, consumed = np.asarray(emitted), np.asarray(consumed)
        self.stats["spec_steps"] += 1
        self.stats["spec_accepted"] += int(consumed[mask].sum() - mask.sum())
        return emitted, consumed

    # -- the serving loop --------------------------------------------------
    def run(self, requests: Sequence[Request]) -> Dict[int, List[int]]:
        """Serve ``requests`` to completion; returns {rid: generated}.

        ``stats`` describes this run alone (reset here); totals across
        runs accumulate in ``lifetime_stats``."""
        self.stats = self._fresh_stats()
        self._last_decode_t = None
        try:
            return self._run(requests)
        finally:
            self._fold_lifetime()

    def _run(self, requests: Sequence[Request]) -> Dict[int, List[int]]:
        for r in requests:
            # fail fast on the whole queue (host-side and cheap): an
            # unservable request deep in the queue must not discard the
            # tokens already generated for the requests ahead of it
            self._validate(r)
        pending = deque(requests)
        active: Dict[int, Request] = {}
        admissions: deque[_Admission] = deque()
        free = deque(range(self.engine.slots))
        chunk = self.engine.prefill_chunk if self.engine.paged else 0
        while pending or active or admissions:
            progressed = False
            # admit pending requests into free slots (claiming pages first
            # in paged mode — a short free list defers admission until an
            # eviction returns pages)
            while pending and free:
                r = pending[0]
                if self.engine.paged and \
                        self._pages.available() < self._pages_needed(r):
                    break
                pending.popleft()
                slot = free.popleft()
                if self.engine.paged:
                    self._alloc_pages(r, slot)
                if self._chunkable(r, chunk):
                    admissions.append(_Admission(r, slot))
                    progressed = True
                else:
                    self._admit(r, slot)
                    progressed = True
                    if self._done(r):       # EOS straight out of prefill
                        self._evict(slot, free)
                    else:
                        active[slot] = r
            # one prefill chunk of the admission at the head of the queue,
            # then fall through to the all-slot decode: long-prompt
            # admission interleaves with in-flight decodes
            if admissions:
                adm = admissions[0]
                progressed = True
                if self._prefill_one_chunk(adm):
                    admissions.popleft()
                    if self._done(adm.r):
                        self._evict(adm.slot, free)
                    else:
                        active[adm.slot] = adm.r
            if active:
                progressed = True
                mask = None
                if self.engine.paged:
                    mask = np.zeros((self.engine.slots,), bool)
                    mask[list(active)] = True
                t0 = time.perf_counter()
                if self.spec_k:
                    emitted, consumed = self._spec_round(active, mask)
                else:
                    self.state, toks = self.engine.decode(self.state,
                                                          active=mask)
                    emitted = np.asarray(toks)[:, None]
                    consumed = np.ones((self.engine.slots,), np.int32)
                now = time.perf_counter()   # emitted is host -> synced
                self.stats["decode_s"] += now - t0
                self.stats["decode_steps"] += 1
                self.stats["decode_slot_steps"] += len(active)
                if self._last_decode_t is not None:
                    self.stats["max_decode_gap_s"] = max(
                        self.stats["max_decode_gap_s"],
                        now - self._last_decode_t)
                self._last_decode_t = now
                for slot, r in list(active.items()):
                    # a spec round can emit several tokens; honor EOS as
                    # soon as it lands (the slot's cache advanced past it,
                    # but a finished request's slot is evicted anyway)
                    for tok in emitted[slot, :consumed[slot]]:
                        r.generated.append(int(tok))
                        self.stats["decode_tokens"] += 1
                        if self._done(r):
                            break
                    if self._done(r):
                        del active[slot]
                        self._evict(slot, free)
                if not active:
                    self._last_decode_t = None
            if not progressed:
                # nothing in flight can ever free the pages the head
                # request needs — admission would spin forever
                raise RuntimeError(
                    "admission deadlock: pending requests but no free "
                    "slot/pages and nothing in flight to evict")
        return {r.rid: list(r.generated) for r in requests}
