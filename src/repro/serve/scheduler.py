"""Continuous batching over the inference engine's request slots.

The scheduler owns the batching POLICY the engine deliberately excludes:
admit a pending request into any free slot (one jitted prefill-insert at
its exact prompt length), run the fused all-slot decode step, harvest
each active slot's token, and evict a slot the moment its request
finishes — EOS token or per-request ``max_new`` budget — so the next
pending request reuses it without reshaping the state.

Each slot's computation is independent of its neighbours (attention,
recurrent state and MoE routing are all per-row), so a request's greedy
output is a function of its prompt alone: deterministic under any
arrival order, slot assignment, or co-batched traffic — the property
``tests/test_serve.py`` pins.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serve.engine import InferenceEngine
from repro.serve.state import InferenceState


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                      # (L,) int32 token ids
    max_new: int = 16
    extras: Dict[str, np.ndarray] = field(default_factory=dict)  # e.g. patches
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None              # last slot served in (telemetry)


class Scheduler:
    """Drives an :class:`InferenceEngine` over a queue of requests."""

    def __init__(self, engine: InferenceEngine, state: InferenceState, *,
                 eos_id: Optional[int] = None):
        self.engine = engine
        self.state = state
        self.eos_id = eos_id
        #: per-slot rid history — lets tests assert slots are actually reused
        self.slot_history: Dict[int, List[int]] = {
            s: [] for s in range(engine.slots)}
        self.stats = {"prefill_tokens": 0, "prefill_s": 0.0,
                      "decode_tokens": 0, "decode_s": 0.0, "decode_steps": 0}

    def _done(self, r: Request) -> bool:
        if not r.generated:
            return False
        if self.eos_id is not None and r.generated[-1] == self.eos_id:
            return True
        return len(r.generated) >= r.max_new

    def _admit(self, r: Request, slot: int) -> None:
        if r.max_new < 1:
            # the prefill itself emits the first greedy token, so a budget
            # below one token is unservable rather than silently exceeded
            raise ValueError(f"request {r.rid}: max_new must be >= 1")
        prompt = np.asarray(r.prompt, np.int32)
        # VLM patch embeddings occupy cache positions ahead of the prompt
        patches = int(np.shape(r.extras["patches"])[0]) \
            if "patches" in r.extras else 0
        if patches + len(prompt) + r.max_new > self.engine.max_len:
            raise ValueError(
                f"request {r.rid}: patches {patches} + prompt {len(prompt)} "
                f"+ max_new {r.max_new} exceeds engine max_len "
                f"{self.engine.max_len} (the cache ring would wrap and "
                f"overwrite live context)")
        inputs = {"tokens": prompt[None, :]}
        for k, v in r.extras.items():
            inputs[k] = np.asarray(v)[None]
        t0 = time.perf_counter()
        self.state, tok = self.engine.insert(self.state, inputs, slot)
        first = int(np.asarray(tok)[0])     # sync point ends the timing
        self.stats["prefill_s"] += time.perf_counter() - t0
        self.stats["prefill_tokens"] += sum(
            int(np.shape(v)[1]) for v in inputs.values())
        r.generated.append(first)
        r.slot = slot
        self.slot_history[slot].append(r.rid)

    def run(self, requests: Sequence[Request]) -> Dict[int, List[int]]:
        """Serve ``requests`` to completion; returns {rid: generated}."""
        pending = deque(requests)
        active: Dict[int, Request] = {}
        free = deque(range(self.engine.slots))
        while pending or active:
            while pending and free:
                slot = free.popleft()
                r = pending.popleft()
                self._admit(r, slot)
                if self._done(r):           # EOS straight out of prefill
                    free.append(slot)
                else:
                    active[slot] = r
            if not active:
                continue
            t0 = time.perf_counter()
            self.state, toks = self.engine.decode(self.state)
            toks = np.asarray(toks)         # sync point ends the timing
            self.stats["decode_s"] += time.perf_counter() - t0
            self.stats["decode_steps"] += 1
            self.stats["decode_tokens"] += len(active)
            for slot, r in list(active.items()):
                r.generated.append(int(toks[slot]))
                if self._done(r):
                    del active[slot]
                    free.append(slot)
        return {r.rid: list(r.generated) for r in requests}
