from repro.serve.speculative.drafter import (  # noqa: F401
    Drafter, ModelDrafter, NgramDrafter,
)
