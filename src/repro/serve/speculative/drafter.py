"""Drafters: token proposers for speculative decoding over the paged pool.

A drafter guesses the next K tokens of each active request; the target
engine then checks all K guesses in ONE fused ``verify`` forward and
accepts the longest greedy-matching prefix.  Because the serve path is
greedy-argmax end to end, speculation is LOSSLESS — every emitted token
is the target model's own argmax regardless of what the drafter proposes;
proposals only decide how many of those argmaxes one decode step yields.
A bad drafter therefore costs speed, never correctness.

Two implementations behind one :class:`Drafter` protocol:

  * :class:`NgramDrafter` — checkpoint-free prompt lookup on host: find
    the most recent earlier occurrence of the context's trailing n-gram
    and propose the tokens that followed it.  Works on any integer token
    stream (LM vocabularies and Dom-ST-style discretized series alike)
    and shines on self-repeating output — exactly what greedy decoding
    produces on templated/structured traffic.
  * :class:`ModelDrafter` — a second, smaller ``ModelConfig`` run through
    its own paged :class:`InferenceEngine` on the same mesh and rule
    tables.  Params arrive through the existing hand-off paths
    (``restore_subtree`` via :meth:`ModelDrafter.from_checkpoint`, or a
    live ``TrainState`` via :meth:`ModelDrafter.from_train_state`).

ModelDrafter sync discipline (the subtle part): the drafter's committed
state only ever consumes CONFIRMED tokens.  Each round it (1) teacher-
forces the tokens confirmed since its last round through ``insert_chunk``
— the committed catch-up, whose final logits yield the first proposal —
then (2) rolls the remaining K-1 proposals autoregressively on a THROWAWAY
copy of the state (the engine is built with ``donate=False``, so the
committed pytree survives) which is discarded after the round.  Discarding
the speculative copy IS the rollback: recurrent/SSM state never advances
through a token the target later rejects, so no per-layer snapshot
plumbing is needed on the draft side.
"""
from __future__ import annotations

from typing import Dict, Optional, Protocol, Tuple, runtime_checkable

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.serve.engine import InferenceEngine

#: propose() input: slot -> (confirmed context tokens, max proposals)
Wants = Dict[int, Tuple[np.ndarray, int]]


@runtime_checkable
class Drafter(Protocol):
    """Host-side proposal policy driven by the scheduler each spec round."""

    def propose(self, wants: Wants) -> Dict[int, np.ndarray]:
        """For each slot, up to ``k`` proposed next tokens (possibly fewer,
        possibly empty).  ``context`` is the request's confirmed stream:
        prompt followed by every token emitted so far."""
        ...

    def release(self, slot: int) -> None:
        """The request in ``slot`` finished; forget any per-slot state
        before the scheduler recycles the slot."""
        ...


class NgramDrafter:
    """Prompt-lookup drafter: propose the continuation of the most recent
    earlier occurrence of the context's trailing n-gram.

    Tries n-gram lengths from ``max_ngram`` down to ``min_ngram`` and
    returns the first hit's following tokens.  The scan is bounded to the
    trailing ``lookback`` tokens so per-step host work stays O(lookback)
    however long a generation runs (losslessness does not depend on WHAT
    is proposed, so bounding the search window is free).  Stateless across
    slots, so :meth:`release` is a no-op."""

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1,
                 lookback: int = 2048):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError(f"need 1 <= min_ngram <= max_ngram, got "
                             f"({min_ngram}, {max_ngram})")
        if lookback <= max_ngram:
            raise ValueError(f"lookback {lookback} must exceed max_ngram")
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram
        self.lookback = lookback

    def _lookup(self, ctx: np.ndarray, k: int) -> Optional[np.ndarray]:
        L = len(ctx)
        for m in range(self.max_ngram, self.min_ngram - 1, -1):
            if L <= m:
                continue
            key = ctx[L - m:]
            win = np.lib.stride_tricks.sliding_window_view(ctx, m)[:L - m]
            hits = np.nonzero((win == key).all(axis=1))[0]
            if not len(hits):
                continue
            j = int(hits[-1])           # most recent earlier occurrence
            cont = ctx[j + m:j + m + k]
            if len(cont):
                return cont
        return None

    def propose(self, wants: Wants) -> Dict[int, np.ndarray]:
        out = {}
        for slot, (ctx, k) in wants.items():
            ctx = np.asarray(ctx, np.int64)[-self.lookback:]
            cont = self._lookup(ctx, k)
            if cont is not None:
                out[slot] = np.asarray(cont, np.int32)
        return out

    def release(self, slot: int) -> None:
        pass


class ModelDrafter:
    """Draft-model proposer: a smaller config served by its own paged
    engine, slot-aligned with the target scheduler's slots.

    The draft engine fully provisions its page pool (one static page row
    per slot, re-cleared on slot reuse through ``assign_pages``) and runs
    UNDONATED so the committed state survives the throwaway speculative
    decodes — see the module docstring for the sync discipline."""

    def __init__(self, cfg: ModelConfig, params=None, *, mesh=None,
                 slots: int = 4, max_len: int = 64, page_size: int = 16,
                 catch_up_chunk: int = 16, dtype=None, seed: int = 0):
        if cfg.num_patches:
            raise ValueError(
                f"{cfg.name}: ModelDrafter drives a token-only stream; "
                f"image-prefixed requests need the NgramDrafter")
        import jax.numpy as jnp
        self.cfg = cfg
        self.chunk = int(catch_up_chunk)
        if self.chunk < 1:
            raise ValueError("catch_up_chunk must be >= 1")
        self.engine = InferenceEngine(
            cfg, mesh=mesh, slots=slots, max_len=max_len,
            dtype=dtype if dtype is not None else jnp.bfloat16,
            paged=True, page_size=page_size, donate=False)
        if params is None:
            params = tfm.init(cfg, jax.random.key(seed))
        self.state = self.engine.init_state(params)
        self._pos: Dict[int, int] = {}  # slot -> committed tokens consumed
        self._ctx: Dict[int, np.ndarray] = {}  # slot -> committed prefix

    # -- hand-off constructors --------------------------------------------
    @classmethod
    def from_checkpoint(cls, cfg: ModelConfig, path: str,
                        **kw) -> "ModelDrafter":
        """Params subtree of a ``repro.launch.train`` TrainState .npz —
        the same ``restore_subtree`` hand-off the target engine uses."""
        d = cls(cfg, **kw)
        params = d.engine.restore_params(path, d.state.params)
        d.state = d.state._replace(params=params)
        return d

    @classmethod
    def from_train_state(cls, train_engine, train_state,
                         **kw) -> "ModelDrafter":
        """Adopt a live trained ``TrainState.params`` in place (no host
        gather), reusing the train engine's mesh like
        ``InferenceEngine.from_train_state`` does."""
        return cls(train_engine.cfg, train_state.params,
                   mesh=train_engine.mesh, **kw)

    # -- the drafting round ------------------------------------------------
    def _assign(self, slot: int) -> None:
        per = self.engine.pages_per_slot
        self.state = self.engine.assign_pages(
            self.state, slot, list(range(slot * per, (slot + 1) * per)))
        self._pos[slot] = 0

    def _catch_up(self, slot: int, ctx: np.ndarray) -> int:
        """Teacher-force the confirmed tokens this slot's committed state
        has not consumed yet (bounded chunks keep jit shapes few); the
        final chunk's greedy argmax is the first proposal.

        Slot reuse detection cannot rely on lengths alone: a recycled
        slot whose NEW request's context is already longer than the old
        committed position would silently teacher-force the new tail
        onto the old request's committed KV.  The committed prefix
        itself is the fingerprint — any mismatch (missed ``release``,
        drafter shared across schedulers) re-assigns the slot and
        replays from scratch."""
        start = self._pos.get(slot)
        committed = self._ctx.get(slot)
        if start is None or start > len(ctx) - 1 or committed is None \
                or not np.array_equal(committed, ctx[:start]):
            self._assign(slot)          # fresh request in a recycled slot
            start = 0
        tok = None
        while start < len(ctx):
            c = ctx[start:start + self.chunk]
            self.state, tok = self.engine.insert_chunk(
                self.state, {"tokens": np.asarray(c, np.int32)[None]},
                slot, start)
            start += len(c)
        self._pos[slot] = len(ctx)
        self._ctx[slot] = np.array(ctx, np.int32, copy=True)
        return int(np.asarray(tok)[0])

    def propose(self, wants: Wants) -> Dict[int, np.ndarray]:
        drafts = {}
        for slot, (ctx, _k) in wants.items():
            ctx = np.asarray(ctx, np.int32)
            total = len(ctx) + 1        # +1: the proposal being drafted
            if total > self.engine.max_len:
                continue                # request outgrew the draft cache
            drafts[slot] = [self._catch_up(slot, ctx)]
        if not drafts:
            return {}
        kmax = max(k for s, (_c, k) in wants.items() if s in drafts)
        mask = np.zeros((self.engine.slots,), bool)
        mask[list(drafts)] = True
        # speculative rollout on a throwaway state: committed state (and
        # its recurrent rows) never sees an unconfirmed token
        st = self.state
        for i in range(1, kmax):
            st, toks = self.engine.decode(st, active=mask)
            toks = np.asarray(toks)
            for slot in drafts:
                if i < wants[slot][1]:
                    drafts[slot].append(int(toks[slot]))
        return {s: np.asarray(d[:wants[s][1]], np.int32)
                for s, d in drafts.items()}

    def release(self, slot: int) -> None:
        self._pos.pop(slot, None)
        self._ctx.pop(slot, None)
