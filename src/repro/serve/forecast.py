"""Dom-ST serving: autoregressive peak-discharge forecasting (the paper's
headline workload) from a trained stacked watershed state.

A :class:`Forecaster` takes the stacked multi-watershed params a
``train.Engine`` checkpointed (leading axis = watershed, sharded over the
data/pod mesh axes exactly as in training) and rolls the network forward
DAY BY DAY over future forcing windows: a ``lax.scan`` over the forecast
horizon inside a per-watershed ``vmap``, each step consuming one trailing
precipitation window + domain prior and emitting that day's discharge.
Per-watershed NSE/MSE against held-out observed discharge come back from
the same jitted call — the serving twin of ``Engine.eval_step``, and
numerically interchangeable with it (each day's window is independent, so
the scanned rollout matches the batched eval; the CLI round-trip test
pins this).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import ModelConfig
from repro.core import domst
from repro.distributed.sharding import (
    logical_sharding, make_rules, resolve_pspec, tree_shardings,
)
from repro.metrics.nse import nse

FORCING_KEYS = ("precip", "target_day", "dist")


class Forecaster:
    """Jitted, sharded multi-watershed discharge forecaster."""

    def __init__(self, cfg: ModelConfig, *, mesh=None,
                 rules: Optional[dict] = None,
                 explicit_shardings: bool = True):
        self.cfg = cfg
        self._mesh = mesh
        self._rules = rules
        self._explicit = explicit_shardings
        # stacked param axes: leading watershed axis -> "batch" (pod/data)
        self._param_axes = domst.stacked_param_specs(cfg)
        self._jit_cache: dict = {}

    @property
    def mesh(self):
        if self._mesh is None:
            from repro.launch.mesh import make_host_mesh
            self._mesh = make_host_mesh()
        return self._mesh

    @property
    def rules(self) -> dict:
        if self._rules is None:
            self._rules = make_rules(self.cfg, mesh=self.mesh)
        return self._rules

    def param_shardings(self, params: Any) -> Any:
        return tree_shardings(self._param_axes, params, self.mesh,
                              self.rules)

    def place_params(self, params: Any) -> Any:
        """device_put ``params`` under the stacked rule-table shardings —
        a no-op for a live hand-off from the stacked train engine."""
        return jax.device_put(params, self.param_shardings(params))

    def _batch_shardings(self, batch: Dict[str, jax.Array]):
        out = {}
        for k, v in batch.items():
            inner = domst.BATCH_AXES.get(k, (None,) * (jnp.ndim(v) - 1))
            axes = ("batch",) + tuple(None if a == "batch" else a
                                      for a in inner)
            out[k] = NamedSharding(self.mesh, resolve_pspec(
                axes, jnp.shape(v), self.mesh, self.rules))
        return out

    def _forecast_fn(self, params: Any, batch: Dict[str, jax.Array]):
        def one_watershed(p, b):
            forcing = {k: b[k] for k in FORCING_KEYS}

            def day(_, f):
                q = domst.forward(p, self.cfg,
                                  jax.tree.map(lambda x: x[None], f))
                return None, q[0]

            _, qhat = jax.lax.scan(day, None, forcing)          # (N,)
            return qhat

        qhat = jax.vmap(one_watershed)(params, batch)           # (W, N)
        obs = batch["discharge"]
        return {"qhat": qhat,
                "nse": jax.vmap(nse)(qhat, obs),
                "mse": jnp.mean(jnp.square(qhat - obs), axis=-1)}

    def __call__(self, params: Any, batch: Dict[str, Any]
                 ) -> Dict[str, jax.Array]:
        """params: stacked (W, ...) tree; batch: (W, N, ...) forcing windows
        plus observed ``discharge`` (W, N).  Returns per-watershed qhat
        (W, N), nse (W,) and mse (W,)."""
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        key = tuple(sorted((k, tuple(jnp.shape(v)), str(v.dtype))
                           for k, v in batch.items()))
        jfn = self._jit_cache.get(key)
        if jfn is None:
            if self._explicit:
                jfn = jax.jit(self._forecast_fn,
                              in_shardings=(self.param_shardings(params),
                                            self._batch_shardings(batch)))
            else:
                jfn = jax.jit(self._forecast_fn)
            self._jit_cache[key] = jfn
        if not self._explicit:
            return jfn(params, batch)
        with self.mesh, logical_sharding(self.mesh, self.rules):
            return jfn(params, batch)
