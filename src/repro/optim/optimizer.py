"""AdamW / SGD on parameter pytrees, with global-norm clipping.

Optimizer state mirrors the param pytree, so the same logical-axis specs
shard it (first/second moments inherit the param's PartitionSpec).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.optim.schedules import make_schedule


class OptState(NamedTuple):
    step: jax.Array
    mu: Any            # first moment (or momentum for sgd)
    nu: Any            # second moment (empty tuple for sgd)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gnorm


def adamw_init(params) -> OptState:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros(), nu=zeros())


def adamw_update(params, grads, state: OptState, tc: TrainConfig):
    sched = make_schedule(tc)
    if tc.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
    else:
        _, gnorm = clip_by_global_norm(grads, 1e30)
    step = state.step + 1
    lr = sched(step)
    b1, b2, eps = tc.b1, tc.b2, tc.eps

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m_new / (1 - b1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (delta + tc.weight_decay * p32)
        return p_new.astype(p.dtype), m_new, v_new

    flat = jax.tree.map(upd, params, grads, state.mu, state.nu)
    outer = jax.tree.structure(params)
    inner = jax.tree.structure((0, 0, 0))
    new_params, new_mu, new_nu = jax.tree.transpose(outer, inner, flat)
    return new_params, OptState(step=step, mu=new_mu, nu=new_nu), \
        {"lr": lr, "grad_norm": gnorm}


def sgd_init(params) -> OptState:
    mu = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu=())


def sgd_update(params, grads, state: OptState, tc: TrainConfig,
               momentum: float = 0.9):
    sched = make_schedule(tc)
    if tc.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
    else:
        _, gnorm = clip_by_global_norm(grads, 1e30)
    step = state.step + 1
    lr = sched(step)

    def upd(p, g, m):
        g = g.astype(jnp.float32)
        m_new = momentum * m + g
        p_new = p.astype(jnp.float32) - lr * m_new
        return p_new.astype(p.dtype), m_new

    flat = jax.tree.map(upd, params, grads, state.mu)
    outer = jax.tree.structure(params)
    inner = jax.tree.structure((0, 0))
    new_params, new_mu = jax.tree.transpose(outer, inner, flat)
    return new_params, OptState(step=step, mu=new_mu, nu=()), \
        {"lr": lr, "grad_norm": gnorm}


def make_optimizer(tc: TrainConfig):
    if tc.optimizer == "adamw":
        return adamw_init, lambda p, g, s: adamw_update(p, g, s, tc)
    if tc.optimizer == "sgd":
        return sgd_init, lambda p, g, s: sgd_update(p, g, s, tc)
    raise ValueError(tc.optimizer)
