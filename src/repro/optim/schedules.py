"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import TrainConfig


def make_schedule(tc: TrainConfig):
    """Returns step -> lr (fp32 scalar)."""
    peak = tc.learning_rate
    warm = max(tc.warmup_steps, 1)
    total = max(tc.total_steps, warm + 1)

    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm_lr = peak * step / warm
        frac = jnp.clip((step - warm) / (total - warm), 0.0, 1.0)
        if tc.schedule == "cosine":
            post = peak * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        elif tc.schedule == "linear":
            post = peak * (1.0 - frac)
        elif tc.schedule == "constant":
            post = jnp.full_like(frac, peak)
        else:
            raise ValueError(tc.schedule)
        return jnp.where(step < warm, warm_lr, post)

    return sched
