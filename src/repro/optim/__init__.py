from repro.optim.optimizer import (  # noqa: F401
    OptState, adamw_init, adamw_update, clip_by_global_norm, make_optimizer,
    sgd_init, sgd_update,
)
from repro.optim.schedules import make_schedule  # noqa: F401
