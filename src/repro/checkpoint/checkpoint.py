"""Pytree checkpointing to .npz (no external deps).

Leaves are flattened with ``jax.tree.flatten_with_path``; key-paths become
npz entry names, so restore round-trips through an *example* pytree of the
same structure (the usual restore-into-init pattern).
"""
from __future__ import annotations

import os
import tempfile
from typing import Any

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(path: str, tree: Any) -> None:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {_path_str(p): np.asarray(v) for p, v in leaves}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    # atomic write
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def restore(path: str, example: Any) -> Any:
    """Restore into the structure of ``example`` (shapes must match)."""
    return restore_subtree(path, example, prefix="")


def restore_subtree(path: str, example: Any, prefix: str) -> Any:
    """Restore the entries under ``prefix/`` into ``example``.

    Lets a consumer rebuild one branch of a larger checkpointed pytree
    without instantiating the rest — e.g. the serve launcher restores only
    the ``params`` subtree of a full TrainState checkpoint (skipping the
    optimizer moments, which can be as large as the model again).
    ``prefix=""`` restores the whole tree.
    """
    pre = f"{prefix}/" if prefix else ""
    with np.load(path) as data:
        paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(example)
        leaves = []
        for p, ex in paths_leaves:
            key = pre + _path_str(p)
            if key not in data:
                raise KeyError(f"checkpoint missing '{key}'")
            arr = data[key]
            if tuple(arr.shape) != tuple(ex.shape):
                raise ValueError(
                    f"shape mismatch for '{key}': ckpt {arr.shape} vs "
                    f"example {ex.shape}")
            leaves.append(jax.numpy.asarray(arr, dtype=ex.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)
