"""Dom-ST: the full domain-aware distributed spatiotemporal network (Fig. 1)
plus the paper's baselines and train/eval steps.

Variants (paper Fig. 3 / Table 1):
  * Singlehead          — 1 CNN head, raster partition, no Pix-Con, no (+P)
  * Singlehead(+P)      — + target-day precipitation into the final layers
  * Distributed-Multihead(+P) == Dom-ST — Pix-Con + dynamic partitioning +
    head-parallel spatial block + (+P)

Multi-watershed training (the paper's input-pipeline distribution, Fig. 2a)
stacks per-watershed model replicas on a leading axis and vmaps the train
step; on the production mesh that axis is sharded over "data"/"pod".
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DomSTConfig, ModelConfig, TrainConfig
from repro.core.partitioner import partition_pixels, static_partition
from repro.core.pixcon import pixcon_block, pixcon_params
from repro.core.spatial import spatial_block, spatial_params
from repro.core.temporal import temporal_block, temporal_params
from repro.distributed.sharding import ParamFactory, is_axes
from repro.metrics.nse import nse
from repro.optim import make_optimizer


# Logical axes per batch input, resolved by the sharding rule table; the
# engine prepends the watershed ("batch" -> pod/data) axis in stacked mode.
BATCH_AXES = {
    "precip": ("batch", "time", "pixels"),
    "target_day": ("batch", "pixels"),
    "dist": ("batch", "pixels"),
    "discharge": ("batch",),
}


def domst_params(cfg: ModelConfig, mk: ParamFactory):
    dc = cfg.domst
    p: Dict[str, Any] = {}
    if dc.use_pixcon:
        p["pixcon"] = pixcon_params(mk, dc.pixcon)
    p["spatial"] = spatial_params(mk, dc)
    p["temporal"] = temporal_params(mk, dc, dc.num_heads * dc.cnn_channels)
    return p


def init(cfg: ModelConfig, key: jax.Array):
    return domst_params(cfg, ParamFactory(key, mode="init"))


def param_specs(cfg: ModelConfig):
    return domst_params(cfg, ParamFactory(mode="spec"))


def stacked_param_specs(cfg: ModelConfig):
    """Spec tree for a stacked multi-watershed replica set: ``param_specs``
    with a leading ``"batch"`` (watershed -> pod/data) axis on every leaf —
    the same transform ``train.state_axes`` applies for the stacked
    TrainState, so the serve-side ``Forecaster`` resolves a checkpointed
    replica stack to the NamedShardings training used."""
    return jax.tree.map(lambda ax: ("batch",) + tuple(ax), param_specs(cfg),
                        is_leaf=is_axes)


def forward(params, cfg: ModelConfig, batch: Dict[str, jax.Array]) -> jax.Array:
    """batch: precip (B,T,P), dist (B,P), target_day (B,P) -> qhat (B,)."""
    dc = cfg.domst
    precip = batch["precip"]
    if dc.use_pixcon:
        x, w = pixcon_block(params["pixcon"], dc.pixcon, precip,
                            batch["dist"], batch["target_day"])
        parts, _ = partition_pixels(x, w, dc.num_heads)
    else:
        parts = static_partition(precip, dc.num_heads)
    feats = spatial_block(params["spatial"], dc, parts)
    qhat = temporal_block(params["temporal"], dc, feats,
                          batch["target_day"] if dc.use_target_day else None)
    return qhat


def loss_fn(params, cfg: ModelConfig, batch) -> Tuple[jax.Array, Dict]:
    qhat = forward(params, cfg, batch)
    err = qhat - batch["discharge"]
    loss = jnp.mean(jnp.square(err))
    return loss, {"mse": loss, "mae": jnp.mean(jnp.abs(err))}


def evaluate(params, cfg: ModelConfig, batch) -> Dict[str, jax.Array]:
    qhat = forward(params, cfg, batch)
    return {"nse": nse(qhat, batch["discharge"]),
            "mse": jnp.mean(jnp.square(qhat - batch["discharge"])),
            "qhat": qhat}


def eval_metrics(params, cfg: ModelConfig, batch) -> Dict[str, jax.Array]:
    """``evaluate`` minus the per-sample qhat series — the scalar payload the
    engine's periodic ``eval_step`` logs (vmapped per watershed when stacked)."""
    ev = evaluate(params, cfg, batch)
    return {"nse": ev["nse"], "mse": ev["mse"]}


# ---------------------------------------------------------------------------
# Train steps — thin veneers over the unified engine (repro/train/).
# Donation is off here because callers of this seed-era signature own the
# param/opt buffers and may reuse them across calls.
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, tc: TrainConfig, *, mesh=None):
    """Single-watershed train step (the paper's per-node unit of work).

    Without ``mesh`` the step is a plain jit (inputs keep whatever sharding
    the caller committed them with, matching the seed behavior); pass a
    mesh to pin rule-table shardings at the jit boundary."""
    from repro.train import Engine
    eng = Engine.for_domst(cfg, tc, mesh=mesh, donate=False,
                           explicit_shardings=mesh is not None)

    def train_step(params, opt_state, batch):
        st, m = eng.step(eng.wrap(params, opt_state), batch)
        return st.params, st.opt_state, m

    return train_step


def make_stacked_train_step(cfg: ModelConfig, tc: TrainConfig, *, mesh=None):
    """Vectorized multi-watershed step: params/batches have a leading
    watershed axis (W, ...) — one replica per watershed (paper Fig. 2a).
    Pass ``mesh`` to shard that axis over its data/pod axes; without it the
    step is a plain jit over caller-placed inputs (seed behavior)."""
    from repro.train import Engine
    eng = Engine.for_domst(cfg, tc, mesh=mesh, stacked=True, donate=False,
                           explicit_shardings=mesh is not None)

    def train_step(params, opt_state, batch):
        st, m = eng.step(eng.wrap(params, opt_state), batch)
        return st.params, st.opt_state, m

    return train_step


def make_reference_stacked_step(cfg: ModelConfig, tc: TrainConfig):
    """The seed hand-rolled jit(vmap) stacked step, retained verbatim as the
    numerical baseline for the engine parity test (tests/test_engine.py)."""
    _, opt_update = make_optimizer(tc)

    def one(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, batch)
        params, opt_state, om = opt_update(params, grads, opt_state)
        return params, opt_state, {**metrics, **om, "loss": loss}

    return jax.jit(jax.vmap(one))


def init_stacked(cfg: ModelConfig, key: jax.Array, num_watersheds: int):
    keys = jax.random.split(key, num_watersheds)
    return jax.vmap(lambda k: init(cfg, k))(keys)
