"""Partitioning module (paper Fig. 1b): dynamically partitions
spatiotemporal pixels by contribution and distributes them to devices.

Pixels are ranked by Pix-Con weight and split into ``num_partitions``
contiguous rank groups; group g feeds spatial-block head g, and heads are
sharded over the "model" mesh axis — so the partition->device mapping of
the paper (each head on its own GPU) becomes partition->head->mesh-shard.

The sort indices are data-dependent (dynamic partitioning, per example);
gradients flow through the gathered *values*.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def partition_pixels(x: jax.Array, w: jax.Array, num_partitions: int
                     ) -> Tuple[jax.Array, jax.Array]:
    """x (B,T,P) weighted inputs, w (B,P) contributions.

    Returns (parts (B, G, T, P//G) -- pixels regrouped by contribution rank,
             order (B, P) -- the permutation used).
    Highest-contribution pixels land in partition 0.
    """
    B, T, P = x.shape
    G = num_partitions
    assert P % G == 0, f"pixels {P} not divisible by partitions {G}"
    # ranking is non-differentiable; gradients flow through gathered values
    # (also avoids differentiating sort, which needs batched gathers that
    # this jaxlib build lacks)
    order = jnp.argsort(-jax.lax.stop_gradient(w), axis=-1)     # (B,P) desc
    xg = jnp.take_along_axis(x, order[:, None, :], axis=2)      # (B,T,P) sorted
    parts = xg.reshape(B, T, G, P // G).transpose(0, 2, 1, 3)   # (B,G,T,P/G)
    return parts, order


def static_partition(x: jax.Array, num_partitions: int) -> jax.Array:
    """Baseline (no domain guidance): contiguous pixel blocks in raster order."""
    B, T, P = x.shape
    G = num_partitions
    assert P % G == 0
    return x.reshape(B, T, G, P // G).transpose(0, 2, 1, 3)
