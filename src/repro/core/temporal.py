"""Temporal block (paper Fig. 1c): stacked LSTM + final layers.

Receives the spatial block's features and — the paper's domain cue — the
*target day's* precipitation (+P) injected into the final layers.
The LSTM cell math matches kernels/lstm_cell (the Pallas hot-spot kernel);
this is the pure-JAX path.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import DomSTConfig
from repro.distributed.sharding import ParamFactory


def lstm_cell_params(mk: ParamFactory, in_dim: int, hidden: int):
    return {
        "wx": mk((in_dim, 4 * hidden), (None, "hidden")),
        "wh": mk((hidden, 4 * hidden), ("hidden", "hidden")),
        "b": mk((4 * hidden,), ("hidden",), init="zeros"),
    }


def lstm_cell(params, x_t: jax.Array, h: jax.Array, c: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """Fused-gate LSTM cell.  x_t (B,D), h/c (B,H) -> (h', c')."""
    gates = x_t @ params["wx"] + h @ params["wh"] + params["b"]
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c_new = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new


def lstm_scan(params, xs: jax.Array) -> jax.Array:
    """xs (B,T,D) -> last hidden (B,H) via lax.scan over T."""
    B = xs.shape[0]
    H = params["wh"].shape[0]
    h0 = jnp.zeros((B, H), xs.dtype)
    c0 = jnp.zeros((B, H), xs.dtype)

    def step(carry, x_t):
        h, c = carry
        h, c = lstm_cell(params, x_t, h, c)
        return (h, c), h

    (_, _), hs = jax.lax.scan(step, (h0, c0), xs.swapaxes(0, 1))
    return hs[-1]


def temporal_params(mk: ParamFactory, dc: DomSTConfig, in_dim: int):
    p = {}
    dim = in_dim
    for layer in range(dc.lstm_layers):
        p[f"lstm{layer}"] = lstm_cell_params(mk, dim, dc.lstm_hidden)
        dim = dc.lstm_hidden
    head_in = dc.lstm_hidden + (dc.num_pixels if dc.use_target_day else 0)
    p["fc1"] = mk((head_in, dc.mlp_hidden), (None, "hidden"))
    p["fc1_b"] = mk((dc.mlp_hidden,), ("hidden",), init="zeros")
    p["fc2"] = mk((dc.mlp_hidden, 1), ("hidden", None))
    p["fc2_b"] = mk((1,), (None,), init="zeros")
    return p


def temporal_block(params, dc: DomSTConfig, feats: jax.Array,
                   target_day: jax.Array | None) -> jax.Array:
    """feats (B,T,F), target_day (B,P) or None -> discharge prediction (B,)."""
    x = feats
    h = None
    for layer in range(dc.lstm_layers):
        lp = params[f"lstm{layer}"]
        B, T, _ = x.shape
        H = lp["wh"].shape[0]
        h0 = jnp.zeros((B, H), x.dtype)
        c0 = jnp.zeros((B, H), x.dtype)

        def step(carry, x_t, lp=lp):
            hh, cc = carry
            hh, cc = lstm_cell(lp, x_t, hh, cc)
            return (hh, cc), hh

        (_, _), hs = jax.lax.scan(step, (h0, c0), x.swapaxes(0, 1))
        x = hs.swapaxes(0, 1)                                    # (B,T,H)
        h = x[:, -1]                                             # last hidden
    if dc.use_target_day and target_day is not None:
        h = jnp.concatenate([h, target_day], axis=-1)            # the (+P) cue
    z = jnp.tanh(h @ params["fc1"] + params["fc1_b"])
    return (z @ params["fc2"] + params["fc2_b"])[:, 0]
