"""Generalized contribution gate — the paper's Pix-Con idea lifted to token
stacks (DESIGN.md §5): a learned per-token contribution weight computed from
the token's own features, applied multiplicatively to the residual stream
after embedding.  For the assigned LM architectures this is an *optional*
feature (cfg.contribution_gate), never forced on published configs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ParamFactory, constrain


def gate_params(mk: ParamFactory, d_model: int, hidden: int = 64):
    return {
        "w1": mk((d_model, hidden), ("embed", "hidden")),
        "b1": mk((hidden,), ("hidden",), init="zeros"),
        "w2": mk((hidden, 1), ("hidden", None)),
    }


def contribution_gate(params, x: jax.Array, temperature: float = 1.0
                      ) -> jax.Array:
    """x (B,S,d) -> gated x; weight in (0,2) (identity at init mean)."""
    h = jnp.tanh(jnp.einsum("bsd,dh->bsh", x, params["w1"].astype(x.dtype))
                 + params["b1"].astype(x.dtype))
    s = jnp.einsum("bsh,ho->bso", h, params["w2"].astype(x.dtype))[..., 0]
    w = 2.0 * jax.nn.sigmoid(s.astype(jnp.float32) / temperature)
    out = x * w[..., None].astype(x.dtype)
    return constrain(out, ("batch", "seq", "embed"))
