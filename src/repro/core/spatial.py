"""Spatial block (paper Fig. 1b): multi-headed multi-channel 1D-CNN.

Each head consumes one pixel partition (its "device share" of the
watershed) and runs a multichannel temporal 1D conv over its pixels.
Heads are vectorized on a leading head axis and sharded over the "model"
mesh axis — the TPU-native form of the paper's one-head-per-GPU layout
(DESIGN.md §2).  The Pallas kernel in kernels/conv1d is the TPU hot-spot
implementation of the same op; this module is the pure-JAX reference path
used for training on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import DomSTConfig
from repro.distributed.sharding import ParamFactory, constrain


def spatial_params(mk: ParamFactory, dc: DomSTConfig):
    pix_per_head = dc.num_pixels // dc.num_heads
    return {
        # (H, K, P/H, C): per-head temporal conv, pixel channels -> C features
        "conv_w": mk((dc.num_heads, dc.kernel_size, pix_per_head,
                      dc.cnn_channels),
                     ("pix_heads", "conv", "pixels", None)),
        "conv_b": mk((dc.num_heads, dc.cnn_channels),
                     ("pix_heads", None), init="zeros"),
        # second conv layer (depth gives the block some capacity)
        "conv2_w": mk((dc.num_heads, dc.kernel_size, dc.cnn_channels,
                       dc.cnn_channels),
                      ("pix_heads", "conv", None, None)),
        "conv2_b": mk((dc.num_heads, dc.cnn_channels),
                      ("pix_heads", None), init="zeros"),
    }


def _conv1d_same(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x (B,T,Cin), w (K,Cin,Cout) -> (B,T,Cout), SAME padding."""
    K = w.shape[0]
    pad_l = (K - 1) // 2
    pad_r = K - 1 - pad_l
    xp = jnp.pad(x, ((0, 0), (pad_l, pad_r), (0, 0)))
    out = sum(jnp.einsum("btc,co->bto", xp[:, i:i + x.shape[1]], w[i])
              for i in range(K))
    return out + b


def spatial_block(params, dc: DomSTConfig, parts: jax.Array) -> jax.Array:
    """parts (B, G, T, P/G) -> features (B, T, G*C).

    G == dc.num_heads; the head axis is vmapped and model-sharded.
    """
    def one_head(xp, w1, b1, w2, b2):
        h = jax.nn.relu(_conv1d_same(xp, w1, b1))
        h = jax.nn.relu(_conv1d_same(h, w2, b2))
        return h                                                 # (B,T,C)

    feats = jax.vmap(one_head, in_axes=(1, 0, 0, 0, 0), out_axes=1)(
        parts, params["conv_w"], params["conv_b"],
        params["conv2_w"], params["conv2_b"])                    # (B,G,T,C)
    feats = constrain(feats, ("batch", "pix_heads", "time", None))
    B, G, T, C = feats.shape
    return feats.transpose(0, 2, 1, 3).reshape(B, T, G * C)
