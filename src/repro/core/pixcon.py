"""Pix-Con — the paper's pixel-contribution block (Fig. 1a).

Computes pixel-specific weights from the domain prior (distance of each
pixel to the nearest water source) together with the pixel's precipitation
statistics over the input window, and transforms the spatiotemporal input
by its local contribution to the outlet discharge:

    feats_p = [dist_p, mean_t precip[t,p], max_t precip[t,p], target_day_p]
    score_p = MLP(feats_p)                       (per pixel)
    w_p     = sigmoid(score_p / temperature)
    x'[t,p] = x[t,p] * w_p            (optionally sum-normalized over p)

The weights are also what the partitioning module (partitioner.py) uses to
assign pixels to spatial-block heads/devices.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import PixConConfig
from repro.distributed.sharding import ParamFactory

NUM_FEATS = 4  # dist, mean, max, target-day


def pixcon_params(mk: ParamFactory, pc: PixConConfig):
    return {
        "w1": mk((NUM_FEATS, pc.hidden), (None, "hidden")),
        "b1": mk((pc.hidden,), ("hidden",), init="zeros"),
        "w2": mk((pc.hidden, 1), ("hidden", None)),
        "b2": mk((1,), (None,), init="zeros"),
    }


def pixel_features(precip: jax.Array, dist: jax.Array,
                   target_day: jax.Array) -> jax.Array:
    """precip (B,T,P), dist (B,P), target_day (B,P) -> (B,P,F)."""
    mean_p = jnp.mean(precip, axis=1)
    max_p = jnp.max(precip, axis=1)
    return jnp.stack([dist, mean_p, max_p, target_day], axis=-1)


def contribution_weights(params, pc: PixConConfig, precip: jax.Array,
                         dist: jax.Array, target_day: jax.Array) -> jax.Array:
    """-> w (B, P) in (0, 1)."""
    f = pixel_features(precip, dist, target_day)
    h = jnp.tanh(jnp.einsum("bpf,fh->bph", f, params["w1"]) + params["b1"])
    s = jnp.einsum("bph,ho->bpo", h, params["w2"])[..., 0] + params["b2"][0]
    w = jax.nn.sigmoid(s / pc.temperature)
    if pc.normalize:
        # keep total contribution mass ~ P (scale-preserving normalization)
        w = w * (w.shape[-1] / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True),
                                           1e-6))
    return w


def pixcon_block(params, pc: PixConConfig, precip: jax.Array,
                 dist: jax.Array, target_day: jax.Array
                 ) -> Tuple[jax.Array, jax.Array]:
    """Returns (transformed precip (B,T,P), weights (B,P))."""
    w = contribution_weights(params, pc, precip, dist, target_day)
    return precip * w[:, None, :], w
