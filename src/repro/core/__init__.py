"""The paper's primary contribution: Dom-ST, a domain-aware distributed
spatiotemporal network (Pix-Con + multihead CNN spatial block + recurrent
temporal block), plus its domain-guided distribution strategy."""
from repro.core import domst, gating, partitioner, pixcon, spatial, temporal  # noqa: F401
