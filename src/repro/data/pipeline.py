"""Input pipeline (the paper's I.P., Fig. 2a).

Builds supervised windows per watershed, normalizes features, and shards
the *watershed set* across workers: ``InputPipeline.shard(node, n_nodes)``
is the paper's "distribute chunks of data (watersheds) to multiple nodes";
``stacked_batches`` vectorizes across watersheds for the IP-D (parallel)
execution mode measured in Table 1.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Sequence, Tuple

import numpy as np

from repro.data.synthetic_hydro import WatershedData


@dataclass
class WatershedWindows:
    """Supervised windows for one watershed."""
    watershed_id: int
    precip: np.ndarray      # (N, T, P) trailing window of pixel precipitation
    target_day: np.ndarray  # (N, P) target day's precipitation (the +P input)
    dist: np.ndarray        # (P,) domain prior (static per watershed)
    discharge: np.ndarray   # (N,) label
    q_mean: float
    q_std: float


def make_training_windows(ws: WatershedData, window: int = 30
                          ) -> WatershedWindows:
    T, P = ws.precip.shape
    n = T - window
    idx = np.arange(n)[:, None] + np.arange(window)[None, :]
    precip = ws.precip[idx]                                     # (N, T, P)
    target_day = ws.precip[window:]                             # day being predicted
    q = ws.discharge[window:]
    p_std = precip.std() + 1e-6
    q_mean, q_std = float(q.mean()), float(q.std() + 1e-6)
    return WatershedWindows(
        watershed_id=ws.watershed_id,
        precip=(precip / p_std).astype(np.float32),
        target_day=(target_day / p_std).astype(np.float32),
        dist=(ws.dist / (ws.dist.max() + 1e-6)).astype(np.float32),
        discharge=((q - q_mean) / q_std).astype(np.float32),
        q_mean=q_mean, q_std=q_std,
    )


def train_test_split(w: WatershedWindows, test_frac: float = 0.2
                     ) -> Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray]]:
    n = len(w.discharge)
    cut = int(n * (1 - test_frac))
    def pack(sl):
        return {
            "precip": w.precip[sl],
            "target_day": w.target_day[sl],
            "dist": np.broadcast_to(w.dist, (len(w.discharge[sl]), len(w.dist))).copy(),
            "discharge": w.discharge[sl],
        }
    return pack(slice(0, cut)), pack(slice(cut, n))


class InputPipeline:
    """Shards watersheds to nodes and yields (mini)batches.

    Modes (paper Table 1):
      * sequential — iterate watersheds one at a time (the 'S' rows);
      * sharded    — this node only sees ``shard(node, n_nodes)`` (IP-D
        across hosts);
      * stacked    — all local watersheds stacked on a leading axis so one
        vectorized train step updates every watershed's replica at once
        (IP-D within a host; on TPU the watershed axis maps to the mesh
        "data"/"pod" axes).
    """

    def __init__(self, windows: Sequence[WatershedWindows], *,
                 batch_size: int = 64, seed: int = 0):
        self.windows = list(windows)
        self.batch_size = batch_size
        self.seed = seed

    def shard(self, node: int, n_nodes: int) -> "InputPipeline":
        return InputPipeline(self.windows[node::n_nodes],
                             batch_size=self.batch_size, seed=self.seed)

    def num_batches(self, n: int) -> int:
        return max(1, n // self.batch_size)

    def batches(self, w: WatershedWindows, epoch: int
                ) -> Iterator[Dict[str, np.ndarray]]:
        """Shuffled minibatches for one watershed."""
        rng = np.random.default_rng(self.seed * 997 + w.watershed_id * 31 + epoch)
        n = len(w.discharge)
        order = rng.permutation(n)
        for i in range(self.num_batches(n)):
            sl = order[i * self.batch_size:(i + 1) * self.batch_size]
            yield {
                "precip": w.precip[sl],
                "target_day": w.target_day[sl],
                "dist": np.broadcast_to(w.dist, (len(sl), len(w.dist))).copy(),
                "discharge": w.discharge[sl],
            }

    def steps_per_epoch(self) -> int:
        """Stacked steps per epoch (bounded by the smallest watershed)."""
        return min(self.num_batches(len(w.discharge)) for w in self.windows)

    def stacked_batches(self, epoch: int) -> Iterator[Dict[str, np.ndarray]]:
        """One batch per step with a leading watershed axis (W, B, ...)."""
        its = [self.batches(w, epoch) for w in self.windows]
        n_steps = self.steps_per_epoch()
        for _ in range(n_steps):
            parts = [next(it) for it in its]
            yield {k: np.stack([p[k] for p in parts]) for k in parts[0]}
