"""Input pipeline (the paper's I.P., Fig. 2a).

Builds supervised windows per watershed, normalizes features, and shards
the *watershed set* across workers: ``InputPipeline.shard(node, n_nodes)``
is the paper's "distribute chunks of data (watersheds) to multiple nodes";
``stacked_batches`` vectorizes across watersheds for the IP-D (parallel)
execution mode measured in Table 1.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterator, List, Sequence, Tuple

import numpy as np

from repro.data.synthetic_hydro import WatershedData


@dataclass
class WatershedWindows:
    """Supervised windows for one watershed."""
    watershed_id: int
    precip: np.ndarray      # (N, T, P) trailing window of pixel precipitation
    target_day: np.ndarray  # (N, P) target day's precipitation (the +P input)
    dist: np.ndarray        # (P,) domain prior (static per watershed)
    discharge: np.ndarray   # (N,) label
    q_mean: float
    q_std: float


def make_domst_windows(num_watersheds: int, days: int
                       ) -> List["WatershedWindows"]:
    """The deterministic synthetic watershed window set.

    Shared by the train and serve launchers: a TrainState checkpoint
    carries no data, so ``repro.launch.serve`` regenerates the SAME
    windows (and therefore the same held-out tail) from the same
    ``(--watersheds, --days)`` arguments — the forecast it reports is
    scored against exactly the split training evaluated."""
    from repro.data.synthetic_hydro import generate_all_watersheds
    data = generate_all_watersheds(num_watersheds, num_days=days)
    return [make_training_windows(w) for w in data.values()]


def make_training_windows(ws: WatershedData, window: int = 30
                          ) -> WatershedWindows:
    T, P = ws.precip.shape
    n = T - window
    idx = np.arange(n)[:, None] + np.arange(window)[None, :]
    precip = ws.precip[idx]                                     # (N, T, P)
    target_day = ws.precip[window:]                             # day being predicted
    q = ws.discharge[window:]
    p_std = precip.std() + 1e-6
    q_mean, q_std = float(q.mean()), float(q.std() + 1e-6)
    return WatershedWindows(
        watershed_id=ws.watershed_id,
        precip=(precip / p_std).astype(np.float32),
        target_day=(target_day / p_std).astype(np.float32),
        dist=(ws.dist / (ws.dist.max() + 1e-6)).astype(np.float32),
        discharge=((q - q_mean) / q_std).astype(np.float32),
        q_mean=q_mean, q_std=q_std,
    )


def _pack(w: WatershedWindows, sl) -> Dict[str, np.ndarray]:
    """The batch dict for an index array / slice into ``w``'s windows."""
    n = len(w.discharge[sl])
    return {
        "precip": w.precip[sl],
        "target_day": w.target_day[sl],
        "dist": np.broadcast_to(w.dist, (n, len(w.dist))).copy(),
        "discharge": w.discharge[sl],
    }


def train_test_split(w: WatershedWindows, test_frac: float = 0.2
                     ) -> Tuple[Dict[str, np.ndarray], Dict[str, np.ndarray]]:
    n = len(w.discharge)
    cut = int(n * (1 - test_frac))
    return _pack(w, slice(0, cut)), _pack(w, slice(cut, n))


def train_split(w: WatershedWindows, test_frac: float = 0.2
                ) -> WatershedWindows:
    """The first (1 - test_frac) of ``w``'s windows as a WatershedWindows.

    Feed THIS to the training pipeline/sources so the tail that
    ``train_test_split``/``stacked_test_batch`` report on stays genuinely
    held out (normalizers and the static dist prior are shared)."""
    cut = int(len(w.discharge) * (1 - test_frac))
    return dataclasses.replace(w, precip=w.precip[:cut],
                               target_day=w.target_day[:cut],
                               discharge=w.discharge[:cut])


def stacked_test_batch(windows: Sequence[WatershedWindows],
                       test_frac: float = 0.2) -> Dict[str, np.ndarray]:
    """Held-out batch with a leading watershed axis (W, N_test, ...) for the
    engine's stacked ``eval_step`` (all watersheds share a window count)."""
    parts = [train_test_split(w, test_frac)[1] for w in windows]
    return {k: np.stack([p[k] for p in parts]) for k in parts[0]}


class InputPipeline:
    """Shards watersheds to nodes and yields (mini)batches.

    Modes (paper Table 1):
      * sequential — iterate watersheds one at a time (the 'S' rows);
      * sharded    — this node only sees ``shard(node, n_nodes)`` (IP-D
        across hosts);
      * stacked    — all local watersheds stacked on a leading axis so one
        vectorized train step updates every watershed's replica at once
        (IP-D within a host; on TPU the watershed axis maps to the mesh
        "data"/"pod" axes).
    """

    def __init__(self, windows: Sequence[WatershedWindows], *,
                 batch_size: int = 64, seed: int = 0):
        self.windows = list(windows)
        self.batch_size = batch_size
        self.seed = seed

    def shard(self, node: int, n_nodes: int) -> "InputPipeline":
        return InputPipeline(self.windows[node::n_nodes],
                             batch_size=self.batch_size, seed=self.seed)

    def num_batches(self, n: int) -> int:
        return max(1, n // self.batch_size)

    def epoch_order(self, w: WatershedWindows, epoch: int) -> np.ndarray:
        """The deterministic shuffle for (seed, watershed, epoch) — the single
        definition shared by ``batches`` and the step-indexed DataSources."""
        rng = np.random.default_rng(self.seed * 997 + w.watershed_id * 31 + epoch)
        return rng.permutation(len(w.discharge))

    def batches(self, w: WatershedWindows, epoch: int
                ) -> Iterator[Dict[str, np.ndarray]]:
        """Shuffled minibatches for one watershed."""
        order = self.epoch_order(w, epoch)
        for i in range(self.num_batches(len(w.discharge))):
            yield _pack(w, order[i * self.batch_size:(i + 1) * self.batch_size])

    def steps_per_epoch(self) -> int:
        """Stacked steps per epoch (bounded by the smallest watershed)."""
        return min(self.num_batches(len(w.discharge)) for w in self.windows)

    def stacked_batches(self, epoch: int) -> Iterator[Dict[str, np.ndarray]]:
        """One batch per step with a leading watershed axis (W, B, ...)."""
        its = [self.batches(w, epoch) for w in self.windows]
        n_steps = self.steps_per_epoch()
        for _ in range(n_steps):
            parts = [next(it) for it in its]
            yield {k: np.stack([p[k] for p in parts]) for k in parts[0]}


# ---------------------------------------------------------------------------
# Step-indexed DataSources (consumed by repro.data.loader.ShardedLoader)
# ---------------------------------------------------------------------------
class WatershedSource:
    """One watershed's shuffled minibatch stream as a ``DataSource``.

    ``host_batch(step)`` is batch ``step % steps_per_epoch`` of the epoch
    ``step // steps_per_epoch`` permutation — the exact ordering
    ``InputPipeline.batches`` yields over successive epochs, but random
    access by global step, so the stream resumes mid-epoch from a cursor.
    """

    def __init__(self, ip: InputPipeline, w: WatershedWindows):
        self.ip = ip
        self.w = w
        self.steps_per_epoch = ip.num_batches(len(w.discharge))
        self._orders: Dict[int, np.ndarray] = {}

    def _order(self, epoch: int) -> np.ndarray:
        order = self._orders.get(epoch)
        if order is None:
            order = self.ip.epoch_order(self.w, epoch)
            # keep at most two epochs, evicting insertion order (FIFO), so a
            # prefetcher straddling an epoch boundary never recomputes and a
            # stale entry can't pin the cache when the source is reused from
            # an earlier cursor; memory stays bounded
            if len(self._orders) >= 2:
                self._orders.pop(next(iter(self._orders)))
            self._orders[epoch] = order
        return order

    def batch_at(self, epoch: int, i: int) -> Dict[str, np.ndarray]:
        bs = self.ip.batch_size
        return _pack(self.w, self._order(epoch)[i * bs:(i + 1) * bs])

    def host_batch(self, step: int) -> Dict[str, np.ndarray]:
        epoch, i = divmod(step, self.steps_per_epoch)
        return self.batch_at(epoch, i)


class StackedSource:
    """All local watersheds stacked on a leading axis (IP-D) as a
    ``DataSource``: step-indexed twin of ``stacked_batches`` — per epoch,
    batches 0..steps_per_epoch-1 of every watershed's own permutation,
    stacked to (W, B, ...)."""

    def __init__(self, ip: InputPipeline):
        self.ip = ip
        self.steps_per_epoch = ip.steps_per_epoch()
        self._subs = [WatershedSource(ip, w) for w in ip.windows]

    def host_batch(self, step: int) -> Dict[str, np.ndarray]:
        epoch, i = divmod(step, self.steps_per_epoch)
        parts = [s.batch_at(epoch, i) for s in self._subs]
        return {k: np.stack([p[k] for p in parts]) for k in parts[0]}
