"""Synthetic token/frame/patch batches for the assigned LM architectures.

Token streams come from a seeded Zipfian n-gram process (so loss actually
decreases during smoke training, unlike uniform noise).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.configs.base import ModelConfig


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int) -> np.ndarray:
    # Zipfian unigram mixed with a repeat-previous process -> learnable
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    flat = rng.choice(vocab, size=int(np.prod(shape)), p=probs)
    toks = flat.reshape(shape)
    # second-order structure: with p=0.3, copy the previous token
    if toks.ndim == 2 and toks.shape[1] > 1:
        copy = rng.random(toks.shape) < 0.3
        copy[:, 0] = False
        shifted = np.roll(toks, 1, axis=1)
        toks = np.where(copy, shifted, toks)
    return toks.astype(np.int32)


def synthetic_token_batch(cfg: ModelConfig, batch: int, seq_len: int,
                          seed: int = 0) -> Dict[str, np.ndarray]:
    """Batch dict matching ``input_specs`` for cfg's family."""
    rng = np.random.default_rng(seed)
    if cfg.family == "audio":
        frames = rng.normal(0, 1, (batch, seq_len, cfg.frontend_dim))
        targets = _zipf_tokens(rng, (batch, seq_len), cfg.vocab_size)
        # HuBERT-style mask: ~8% spans masked; loss only on masked frames
        mask = (rng.random((batch, seq_len)) < 0.08).astype(np.float32)
        return {"frames": frames.astype(np.float32), "targets": targets,
                "loss_mask": mask}
    if cfg.family == "vlm":
        p = cfg.num_patches
        text_len = seq_len - p
        toks = _zipf_tokens(rng, (batch, text_len + 1), cfg.vocab_size)
        patches = rng.normal(0, 1, (batch, p, cfg.frontend_dim))
        return {"patches": patches.astype(np.float32),
                "tokens": toks[:, :-1],
                "targets": toks[:, 1:]}
    toks = _zipf_tokens(rng, (batch, seq_len + 1), cfg.vocab_size)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class TokenSource:
    """Endless synthetic token stream as a ``DataSource`` for the
    ShardedLoader: the batch at global step ``i`` is seeded ``seed + i``,
    so the step counter is the resumable stream cursor (restoring a
    checkpoint at step N and restarting the source there replays exactly
    the continuation an uninterrupted run would have produced)."""

    steps_per_epoch = None

    def __init__(self, cfg: ModelConfig, batch_size: int, seq_len: int, *,
                 seed: int = 0):
        self.cfg = cfg
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.seed = seed

    def host_batch(self, step: int) -> Dict[str, np.ndarray]:
        return synthetic_token_batch(self.cfg, self.batch_size, self.seq_len,
                                     seed=self.seed + step)
