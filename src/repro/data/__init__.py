from repro.data.synthetic_hydro import WatershedData, generate_watershed, generate_all_watersheds  # noqa: F401
from repro.data.pipeline import (  # noqa: F401
    InputPipeline, StackedSource, WatershedSource, make_domst_windows,
    make_training_windows, stacked_test_batch, train_split,
    train_test_split,
)
from repro.data.tokens import TokenSource, synthetic_token_batch  # noqa: F401
from repro.data.loader import DataSource, ShardedLoader  # noqa: F401
