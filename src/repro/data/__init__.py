from repro.data.synthetic_hydro import WatershedData, generate_watershed, generate_all_watersheds  # noqa: F401
from repro.data.pipeline import InputPipeline, make_training_windows  # noqa: F401
from repro.data.tokens import synthetic_token_batch  # noqa: F401
