"""Async sharded data loading — the paper's input pipeline ("I.P.", Fig. 2a).

The paper's 12.6x end-to-end speedup assumes the input pipeline keeps
every replica fed: watersheds are distributed to nodes, and each node's
device step must never wait on host-side windowing or H2D transfer.  The
seed reproduced the *distribution* (``InputPipeline.shard`` /
``stacked_batches``) but drove it with synchronous python loops, so every
``Engine.step`` paid host batch assembly + transfer on the critical path.

This module closes that gap with two pieces:

  * :class:`DataSource` — a *random-access* batch protocol:
    ``host_batch(step)`` returns the host (numpy) batch for a global step
    index.  Epoch shuffles are seeded deterministically from
    ``(seed, watershed, epoch)`` with ``epoch = step // steps_per_epoch``,
    so the global step doubles as a **resumable stream cursor**: restoring
    a checkpoint and restarting the source at ``step`` replays *exactly*
    the stream an uninterrupted run would have seen — mid-epoch included,
    identically for the Dom-ST and LM paths.

  * :class:`ShardedLoader` — wraps a DataSource and an
    ``Engine``: each host batch is placed onto the mesh with
    ``jax.device_put`` under the engine's ``NamedSharding``s (resolved
    from the same logical-axis rule tables the jitted step uses, so the
    arrays arrive already laid out for ``in_shardings``), and a
    background thread runs ``prefetch`` batches ahead of the consumer
    (depth >= 2 => double buffering).  The training loop collapses to
    ``for batch in loader: state, m = engine.step(state, batch)``.

``prefetch=0`` degrades to the synchronous path (same batches, same
placement, no thread) — the parity baseline for tests and for
``benchmarks/loader_bench.py``.
"""
from __future__ import annotations

import itertools
import queue
import threading
from typing import Dict, Iterator, Optional, Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class DataSource(Protocol):
    """Random-access host-batch stream indexed by global step."""

    #: steps per epoch for epoch-shuffled sources; None for endless streams
    steps_per_epoch: Optional[int]

    def host_batch(self, step: int) -> Dict[str, np.ndarray]:
        """The host batch for global step ``step`` (deterministic)."""
        ...


class ShardedLoader:
    """Prefetching device-put iterator over a :class:`DataSource`.

    Args:
      source: the batch stream (``host_batch(step)``).
      engine: a ``repro.train.Engine`` — supplies ``place_batch``, which
        device_puts host arrays under the rule-table shardings (incl. the
        leading watershed axis in stacked mode).
      prefetch: background-queue depth; >= 2 double-buffers H2D transfer
        behind compute, 0 means fully synchronous (no thread).
      start_step: the stream cursor to (re)start from — pass the restored
        ``int(state.step)`` to resume a checkpointed run in place.
      num_steps: batches yielded per ``iter()`` (None = endless).

    ``loader.cursor`` always names the next step to be consumed, so after
    ``state, m = engine.step(state, batch)`` it equals ``int(state.step)``
    and can be checkpointed implicitly with the TrainState.
    """

    _DONE = object()
    _ERR = object()

    def __init__(self, source: DataSource, engine, *, prefetch: int = 2,
                 start_step: int = 0, num_steps: Optional[int] = None):
        self.source = source
        self.engine = engine
        self.prefetch = int(prefetch)
        self.cursor = int(start_step)
        self.num_steps = num_steps

    def _steps(self):
        if self.num_steps is None:
            return itertools.count(self.cursor)
        return range(self.cursor, self.cursor + int(self.num_steps))

    def _place(self, step: int):
        return self.engine.place_batch(self.source.host_batch(step))

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        if self.prefetch <= 0:                 # synchronous reference path
            for s in self._steps():
                batch = self._place(s)
                self.cursor = s + 1
                yield batch
            return

        steps = self._steps()
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def put(item) -> bool:
            # bounded put that gives up once the consumer has left, so an
            # abandoned iterator never wedges the worker on a full queue
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def worker() -> None:
            try:
                for s in steps:
                    if stop.is_set() or not put((s, self._place(s))):
                        return
            except BaseException as e:         # re-raised on the consumer side
                put((self._ERR, e))
            else:
                put((self._DONE, None))

        t = threading.Thread(target=worker, name="sharded-loader-prefetch",
                             daemon=True)
        t.start()
        try:
            while True:
                tag, item = q.get()
                if tag is self._DONE:
                    return
                if tag is self._ERR:
                    raise item
                self.cursor = tag + 1
                yield item
        finally:
            stop.set()
