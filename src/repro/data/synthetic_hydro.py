"""Synthetic 23-watershed hydrology dataset (the paper's data gate).

The paper trains on CRU pixellated daily precipitation + USGS discharge for
23 Iowa watersheds — data we cannot ship.  This generator replaces it with
a *physically structured* simulator so that (a) NSE is a meaningful metric,
(b) the Pix-Con hypothesis is testable: each pixel's contribution to outlet
discharge genuinely depends on its distance to the nearest water source.

Per watershed w (seeded, so the 23 watersheds differ in climate and
geomorphology, as in the paper §2):

  precip[t, p]   spatially correlated lognormal storm fields with seasonal
                 modulation and storm advection,
  dist[p]        distance of pixel p to the nearest stream channel,
  discharge[t] = sum_p k_p * sum_tau g(tau; d_p) * precip[t - tau, p]
                 + baseflow + noise

where the unit-hydrograph kernel g has per-pixel lag/attenuation growing
with dist[p] (near-stream pixels respond fast and strongly -> exactly the
domain knowledge Pix-Con is supposed to recover), and k_p is a soil/land
-cover runoff coefficient.  Flash floods are driven by same-day precipitation
— the paper's motivation for the (+P) target-day input.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np


@dataclass
class WatershedData:
    watershed_id: int
    precip: np.ndarray        # (T, P) daily precipitation per pixel
    dist: np.ndarray          # (P,) distance of pixel to nearest water source
    discharge: np.ndarray     # (T,) outlet discharge
    grid_hw: Tuple[int, int]  # pixel grid shape (h, w), P = h*w


def _stream_mask(h: int, w: int, rng: np.random.Generator) -> np.ndarray:
    """Random meandering stream through the grid; True = channel pixel."""
    mask = np.zeros((h, w), bool)
    col = rng.integers(0, w)
    for row in range(h):
        mask[row, col] = True
        col = int(np.clip(col + rng.integers(-1, 2), 0, w - 1))
        mask[row, col] = True
    return mask


def _distance_to(mask: np.ndarray) -> np.ndarray:
    """Chebyshev distance transform (small grids; O(P * channels))."""
    h, w = mask.shape
    ys, xs = np.nonzero(mask)
    yy, xx = np.mgrid[0:h, 0:w]
    d = np.min(np.maximum(np.abs(yy[..., None] - ys),
                          np.abs(xx[..., None] - xs)), axis=-1)
    return d.astype(np.float32)


def _storm_fields(T: int, h: int, w: int, rng: np.random.Generator,
                  wet_prob: float, intensity: float) -> np.ndarray:
    """Spatially correlated storms: random centers + gaussian footprints,
    advected across days; seasonal (annual sine) modulation."""
    P = h * w
    yy, xx = np.mgrid[0:h, 0:w]
    season = 1.0 + 0.8 * np.sin(2 * np.pi * np.arange(T) / 365.0
                                + rng.uniform(0, 2 * np.pi))
    out = np.zeros((T, h, w), np.float32)
    t = 0
    while t < T:
        if rng.random() < wet_prob:
            dur = int(rng.integers(1, 4))
            cy, cx = rng.uniform(0, h), rng.uniform(0, w)
            vy, vx = rng.normal(0, 1.0, 2)
            sig = rng.uniform(1.5, max(h, w) / 2)
            amp = intensity * rng.lognormal(0.0, 0.7)
            for k in range(dur):
                if t + k >= T:
                    break
                fy, fx = cy + vy * k, cx + vx * k
                foot = np.exp(-(((yy - fy) ** 2 + (xx - fx) ** 2)
                                / (2 * sig ** 2)))
                out[t + k] += amp * season[t + k] * foot.astype(np.float32)
            t += dur
        else:
            t += 1
    out += rng.gamma(0.3, 0.5, (T, h, w)).astype(np.float32) * 0.1  # drizzle
    return out.reshape(T, P)


def generate_watershed(watershed_id: int, *, num_days: int = 1460,
                       grid: Tuple[int, int] = (8, 8),
                       seed: int = 0) -> WatershedData:
    """One watershed with its own climate/geomorphology (seeded)."""
    rng = np.random.default_rng(seed * 1000 + watershed_id)
    h, w = grid
    P = h * w

    mask = _stream_mask(h, w, rng)
    dist = _distance_to(mask).reshape(P)

    wet_prob = rng.uniform(0.15, 0.45)       # climate varies by watershed
    intensity = rng.uniform(0.5, 2.0)
    precip = _storm_fields(num_days, h, w, rng, wet_prob, intensity)

    # Per-pixel routing: lag and attenuation grow with distance-to-stream.
    runoff_k = rng.uniform(0.3, 1.0, P).astype(np.float32)      # soil/landcover
    max_lag = 14
    # unit hydrograph per pixel: gamma-like kernel peaking at lag ~ dist/2.
    # Near-stream pixels respond the SAME DAY (tau=0) — the paper's
    # flash-flood physics ("the target day's precipitation [is] the primary
    # contributing factor of flash floods"): kern(tau) uses tau+1 so
    # dist=0 pixels peak at tau=0.
    taus = np.arange(1, max_lag + 1, dtype=np.float32)[None, :]  # (1, L)
    peak = (dist[:, None] / 2.0) + 1.0
    kern = (taus / peak) * np.exp(1.0 - taus / peak)             # (P, L), peak=1
    kern = kern / np.maximum(kern.sum(1, keepdims=True), 1e-6)
    atten = np.exp(-dist / (0.35 * max(h, w)))                   # near-stream dominates
    weight = (runoff_k * atten)[:, None] * kern                  # (P, L)

    # discharge[t] = sum_p sum_l weight[p,l] * precip[t-l, p]
    T = num_days
    q = np.zeros(T, np.float32)
    for l in range(max_lag):
        shifted = np.zeros((T, P), np.float32)
        shifted[l:] = precip[:T - l]
        q += shifted @ weight[:, l]
    base = rng.uniform(0.5, 2.0)
    q = q + base + rng.normal(0, 0.02 * q.std(), T).astype(np.float32)

    return WatershedData(watershed_id=watershed_id, precip=precip,
                         dist=dist, discharge=q.astype(np.float32),
                         grid_hw=grid)


def generate_all_watersheds(n: int = 23, **kw) -> Dict[int, WatershedData]:
    """The paper's 23-watershed dataset."""
    return {i: generate_watershed(i, **kw) for i in range(n)}
