"""Nash–Sutcliffe efficiency — the paper's model-evaluation metric [13,14].

NSE = 1 - sum((obs - sim)^2) / sum((obs - mean(obs))^2)

NSE = 1 is a perfect model; NSE = 0 matches the observed mean; NSE < 0 is
worse than predicting the mean.
"""
from __future__ import annotations

import jax.numpy as jnp


def nse(sim, obs) -> jnp.ndarray:
    sim = jnp.asarray(sim, jnp.float32).reshape(-1)
    obs = jnp.asarray(obs, jnp.float32).reshape(-1)
    num = jnp.sum(jnp.square(obs - sim))
    den = jnp.sum(jnp.square(obs - jnp.mean(obs)))
    return 1.0 - num / jnp.maximum(den, 1e-12)
