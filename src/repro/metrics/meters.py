"""Host-side metric accumulation for training loops, over the registry.

:class:`Meter` keeps its historical ``update``/``mean``/``last``/
``elapsed``/``summary`` API, but every ``update`` now lands in a
:class:`~repro.obs.registry.Histogram` of a shared
:class:`~repro.obs.registry.MetricRegistry` — so a training loop that
meters ``loss`` also gets loss quantiles for free, and the launcher's
``--metrics-out`` JSONL dump sees every metered key (as
``<prefix><key>``) without a second bookkeeping path.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.obs.registry import Histogram, MetricRegistry


class Meter:
    """Thin named-histogram view over a :class:`MetricRegistry`.

    ``Meter()`` with no arguments owns a private registry (the historical
    standalone behavior); pass ``registry=`` to share the launcher's
    store, and ``prefix=`` to namespace the metered keys in it
    (``prefix="train."`` puts ``update(loss=...)`` under ``train.loss``).
    """

    def __init__(self, registry: Optional[MetricRegistry] = None,
                 prefix: str = "") -> None:
        self.registry = registry if registry is not None else MetricRegistry()
        self.prefix = prefix
        self._keys: Dict[str, str] = {}     # metered key -> registry name

    def _hist(self, key: str) -> Histogram:
        name = self._keys.get(key)
        if name is None:
            name = self._keys[key] = f"{self.prefix}{key}"
        return self.registry.histogram(name)

    def update(self, **metrics: float) -> None:
        for k, v in metrics.items():
            self._hist(k).record(float(v))

    def mean(self, key: str) -> float:
        return self._hist(key).mean

    def last(self, key: str) -> float:
        return self._hist(key).last

    def elapsed(self) -> float:
        return self.registry.elapsed()

    def summary(self) -> Dict[str, float]:
        return {k: self.mean(k) for k in self._keys}
