"""Lightweight host-side metric accumulation for training loops."""
from __future__ import annotations

import collections
import time
from typing import Dict, List


class Meter:
    def __init__(self) -> None:
        self._vals: Dict[str, List[float]] = collections.defaultdict(list)
        self._t0 = time.perf_counter()

    def update(self, **metrics: float) -> None:
        for k, v in metrics.items():
            self._vals[k].append(float(v))

    def mean(self, key: str) -> float:
        v = self._vals.get(key, [])
        return sum(v) / len(v) if v else float("nan")

    def last(self, key: str) -> float:
        v = self._vals.get(key, [])
        return v[-1] if v else float("nan")

    def elapsed(self) -> float:
        return time.perf_counter() - self._t0

    def summary(self) -> Dict[str, float]:
        return {k: self.mean(k) for k in self._vals}
