from repro.metrics.nse import nse  # noqa: F401
from repro.metrics.meters import Meter  # noqa: F401
