from repro.models import attention, layers, mlp, moe, rglru, ssm, transformer  # noqa: F401
