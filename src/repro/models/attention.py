"""Attention: GQA projections + flash-style chunked attention + KV cache.

Three execution paths, all pure ``jax.lax`` (TPU-friendly, no S x S score
materialization):

* ``flash_attention``      — global (causal or bidirectional): online-softmax
                             scan over KV blocks; memory O(S * block).
* ``local_attention``      — sliding window: scan over Q blocks, each
                             attending to a fixed-size KV slice (window+block);
                             FLOPs O(S * window), the sub-quadratic path.
* ``decode_attention``     — one query token vs. a (possibly ring-buffer)
                             cache with per-slot absolute positions.

Layouts are BSHD: q (B, S, Hq, D); k/v (B, S, Hkv, D).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParamFactory, constrain
from repro.kernels.common import use_paged_attn_kernel
from repro.kernels.paged_attn.ops import paged_attention_fused
from repro.models.layers import apply_norm, apply_rope, norm_params

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def attn_params(mk: ParamFactory, cfg: ModelConfig):
    d, hq, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim()
    p = {
        "wq": mk((d, hq, dh), ("embed", "heads", "head_dim")),
        "wk": mk((d, hkv, dh), ("embed", "kv_heads", "head_dim")),
        "wv": mk((d, hkv, dh), ("embed", "kv_heads", "head_dim")),
        "wo": mk((hq, dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = mk((hq, dh), ("heads", "head_dim"), init="zeros")
        p["bk"] = mk((hkv, dh), ("kv_heads", "head_dim"), init="zeros")
        p["bv"] = mk((hkv, dh), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = norm_params(mk, "rmsnorm", dh)
        p["k_norm"] = norm_params(mk, "rmsnorm", dh)
    return p


def qkv_project(params, cfg: ModelConfig, x: jax.Array, positions: jax.Array):
    """x (B,S,d) -> q (B,S,Hq,D), k/v (B,S,Hkv,D) with rope applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = apply_norm(params["q_norm"], "rmsnorm", q)
        k = apply_norm(params["k_norm"], "rmsnorm", k)
    if cfg.rope:
        # rope over (B,S,H,D): move head before seq for broadcasting
        q = apply_rope(q.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta).swapaxes(1, 2)
        k = apply_rope(k.swapaxes(1, 2), positions[:, None, :], cfg.rope_theta).swapaxes(1, 2)
    q = constrain(q, ("batch", "seq", "heads", "head_dim"))
    k = constrain(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = constrain(v, ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


def out_project(params, x: jax.Array) -> jax.Array:
    """(B,S,Hq,D) -> (B,S,d)."""
    out = jnp.einsum("bshk,hkd->bsd", x, params["wo"].astype(x.dtype))
    return constrain(out, ("batch", "seq", "embed"))


# ---------------------------------------------------------------------------
# Flash attention (global): online softmax over KV blocks
# ---------------------------------------------------------------------------
def _gqa_scores(q, k, scale, softcap_val):
    """q (B,Sq,Hkv,G,D) x k (B,Bk,Hkv,D) -> scores (B,Hkv,G,Sq,Bk), fp32."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32) * scale
    if softcap_val:
        s = softcap_val * jnp.tanh(s / softcap_val)
    return s


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    softcap_val: float = 0.0,
                    block_k: int = 1024,
                    block_q: int = 1024,
                    q_positions: Optional[jax.Array] = None,
                    k_positions: Optional[jax.Array] = None) -> jax.Array:
    """Online-softmax attention, O(block_q*block_k) live score memory.

    q (B,Sq,Hq,D), k/v (B,Sk,Hkv,D) -> (B,Sq,Hq,D).

    Long queries are processed in ``block_q`` tiles via ``lax.map``; each
    tile runs the online-softmax scan over KV tiles.  (Causal tiles scan
    the full KV range with masking — the rectangle-vs-triangle FLOP
    overcount is noted in EXPERIMENTS.md §Roofline.)
    """
    B, Sq, Hq, D = q.shape
    if Sq > block_q:
        nqb = (Sq + block_q - 1) // block_q
        pad = nqb * block_q - Sq
        if q_positions is None:
            q_positions = jnp.arange(Sq, dtype=jnp.int32)[None, :].repeat(B, 0)
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # padded tail positions masked out via position < 0
        qpos = jnp.pad(q_positions, ((0, 0), (0, pad)), constant_values=-1)
        qs = qp.reshape(B, nqb, block_q, Hq, D).transpose(1, 0, 2, 3, 4)
        qposs = qpos.reshape(B, nqb, block_q).transpose(1, 0, 2)

        def one(args):
            qb, qpb = args
            return flash_attention(
                qb, k, v, causal=causal, softcap_val=softcap_val,
                block_k=block_k, block_q=block_q,
                q_positions=qpb, k_positions=k_positions)

        outs = jax.lax.map(one, (qs, qposs))                    # (nqb,B,Bq,Hq,D)
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nqb * block_q, Hq, D)
        return out[:, :Sq]
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / np.sqrt(D)
    qr = q.reshape(B, Sq, Hkv, G, D)

    block_k = min(block_k, Sk)
    nkb = (Sk + block_k - 1) // block_k
    pad = nkb * block_k - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if q_positions is None:
        q_positions = jnp.arange(Sq)[None, :].repeat(B, 0)
    if k_positions is None:
        k_positions = jnp.arange(Sk)[None, :].repeat(B, 0)
    k_positions = jnp.pad(k_positions, ((0, 0), (0, pad)), constant_values=-1)

    ks = k.reshape(B, nkb, block_k, Hkv, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nkb, block_k, Hkv, D).transpose(1, 0, 2, 3, 4)
    kps = k_positions.reshape(B, nkb, block_k).transpose(1, 0, 2)

    def body(carry, blk):
        m, l, acc = carry
        kb, vb, kp = blk
        s = _gqa_scores(qr, kb, scale, softcap_val)            # (B,Hkv,G,Sq,Bk)
        mask = (kp[:, None, None, None, :] >= 0)
        if causal:
            mask = mask & (kp[:, None, None, None, :]
                           <= q_positions[:, None, None, :, None])
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb).astype(jnp.float32)
        acc_new = acc * alpha[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, kps))
    out = acc / jnp.maximum(l, 1e-30)[..., None]               # (B,Hkv,G,Sq,D)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Block-Q attention for TRAINING: lax.map over checkpointed Q-blocks.
#
# Differentiating the online-softmax scan stores per-step carries (O(S^2))
# — catastrophic.  Here each Q block computes a full softmax row against
# all of K in one shot inside jax.checkpoint, so the backward pass
# rematerializes one block's scores at a time: live memory
# O(B*H*block_q*Sk), saved residuals O(inputs) only.
# ---------------------------------------------------------------------------
def blockq_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     causal: bool = True,
                     softcap_val: float = 0.0,
                     block_q: int = 512) -> jax.Array:
    """Training-path attention.  q (B,Sq,Hq,D), k/v (B,Sk,Hkv,D)."""
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / np.sqrt(D)
    block_q = min(block_q, Sq)
    nqb = (Sq + block_q - 1) // block_q
    pad = nqb * block_q - Sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qr = q.reshape(B, nqb, block_q, Hkv, G, D)
    k_pos = jnp.arange(Sk)

    @jax.checkpoint
    def per_block(qb, q_pos, k, v):
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, k).astype(jnp.float32) * scale
        if softcap_val:
            s = softcap_val * jnp.tanh(s / softcap_val)
        if causal:
            mask = k_pos[None, :] <= q_pos[:, None]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), v)

    def one(i):
        q_pos = i * block_q + jnp.arange(block_q)
        return per_block(qr[:, i], q_pos, k, v)                 # (B,Hkv,G,bq,D)

    outs = jax.lax.map(one, jnp.arange(nqb))                    # (nqb,B,Hkv,G,bq,D)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, nqb * block_q, Hq, D)
    return out[:, :Sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# Local (sliding-window) attention: scan over Q blocks
# ---------------------------------------------------------------------------
def local_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: int,
                    causal: bool = True,
                    softcap_val: float = 0.0,
                    block_q: int = 512) -> jax.Array:
    """Sliding-window attention, FLOPs O(S * (window + block_q)).

    Each Q block of length Bq attends to the KV slice of length W+Bq ending
    at the block's last position (clamped at 0); the band mask enforces
    ``0 <= q_pos - k_pos < window`` (and causality).
    """
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / np.sqrt(D)
    block_q = min(block_q, S)
    nqb = (S + block_q - 1) // block_q
    pad = nqb * block_q - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    span = window + block_q                                     # KV slice length
    # pad KV at the FRONT by span (front slots masked via position < 0) and
    # at the END by the q padding so no dynamic_slice ever clamps (clamping
    # would silently misalign k positions).
    kp = jnp.pad(k, ((0, 0), (span, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (span, pad), (0, 0), (0, 0)))
    S_orig = S

    qr = q.reshape(B, nqb, block_q, Hkv, G, D)

    @jax.checkpoint
    def per_block(i):
        qb = qr[:, i]                                           # (B,Bq,Hkv,G,D)
        q_pos = i * block_q + jnp.arange(block_q)               # (Bq,)
        end = i * block_q + block_q                             # kv slice end (orig idx)
        start = end - span + span                               # padded-idx start == end
        kb = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
        k_pos = end - span + jnp.arange(span)                   # (span,) absolute, <0 invalid
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qb, kb).astype(jnp.float32) * scale
        if softcap_val:
            s = softcap_val * jnp.tanh(s / softcap_val)
        delta = q_pos[:, None] - k_pos[None, :]                 # (Bq, span)
        mask = (k_pos[None, :] >= 0) & (k_pos[None, :] < S_orig) \
            & (delta < window)
        if causal:
            mask = mask & (delta >= 0)
        else:
            mask = mask & (delta > -window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb)
        return o                                                # (B,Hkv,G,Bq,D)

    outs = jax.lax.map(per_block, jnp.arange(nqb))              # (nqb,B,Hkv,G,Bq,D)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, nqb * block_q, Hkv, G, D)
    out = out[:, :S].reshape(B, S, Hq, D)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# KV cache (global or ring-buffer for local layers)
# ---------------------------------------------------------------------------
class KVCache(NamedTuple):
    k: jax.Array          # (B, L, Hkv, D)
    v: jax.Array          # (B, L, Hkv, D)
    pos: jax.Array        # (B, L) absolute position of each slot, -1 = empty


def kv_cache_axes():
    return KVCache(
        k=("batch", "cache_seq", "kv_heads", "head_dim"),
        v=("batch", "cache_seq", "kv_heads", "head_dim"),
        pos=("batch", "cache_seq"),
    )


def init_kv_cache(batch: int, length: int, hkv: int, dh: int,
                  dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, length, hkv, dh), dtype),
        v=jnp.zeros((batch, length, hkv, dh), dtype),
        pos=jnp.full((batch, length), -1, jnp.int32),
    )


def cache_length(cfg: ModelConfig, kind: str, max_len: int) -> int:
    """Ring length: full context for global layers, window for local."""
    if kind == "local":
        return min(cfg.window, max_len)
    return max_len


def fill_cache_from_prefill(cache: KVCache, k: jax.Array, v: jax.Array) -> KVCache:
    """Write a full prefill's K/V (B,S,Hkv,D) into a length-L ring cache."""
    B, S = k.shape[0], k.shape[1]
    L = cache.k.shape[1]
    take = min(S, L)
    k_t = k[:, S - take:]
    v_t = v[:, S - take:]
    pos_t = jnp.arange(S - take, S, dtype=jnp.int32)
    slots = pos_t % L                                           # (take,)
    new_k = cache.k.at[:, slots].set(k_t.astype(cache.k.dtype))
    new_v = cache.v.at[:, slots].set(v_t.astype(cache.v.dtype))
    new_pos = cache.pos.at[:, slots].set(pos_t[None, :].repeat(B, 0))
    return KVCache(new_k, new_v, new_pos)


def attend_masked(cfg: ModelConfig, q: jax.Array, k_all: jax.Array,
                  v_all: jax.Array, kp: jax.Array, qpos: jax.Array, *,
                  window: Optional[int] = None) -> jax.Array:
    """Projection-free core of :func:`attend_cached`: q (B,Sq,Hq,D)
    against gathered cache entries k/v (B,L,Hkv,D) whose absolute
    positions are kp (B,L), -1 = empty -> (B,Sq,Hq,D).  This is the lax
    counterpart of ``kernels.paged_attn.paged_attention_fused``."""
    B, Sq, Hq, dh = q.shape
    Hkv = k_all.shape[2]
    G = Hq // Hkv
    qr = q.reshape(B, Sq, Hkv, G, dh)
    scale = 1.0 / np.sqrt(dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr,
                   k_all.astype(q.dtype)).astype(jnp.float32) * scale
    if cfg.attn_softcap:
        s = cfg.attn_softcap * jnp.tanh(s / cfg.attn_softcap)
    kpb = kp[:, None, None, None, :]                            # (B,1,1,1,L)
    pq = qpos[:, None, None, :, None]                           # (B,1,1,Sq,1)
    mask = (kpb >= 0) & (kpb <= pq)
    if window is not None:
        mask = mask & (pq - kpb < window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(q.dtype),
                   v_all.astype(q.dtype))
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, dh)


def attend_cached(params, cfg: ModelConfig, q: jax.Array, k_all: jax.Array,
                  v_all: jax.Array, kp: jax.Array, qpos: jax.Array, *,
                  window: Optional[int] = None) -> jax.Array:
    """Masked attention of q (B,Sq,Hq,D) against gathered cache entries
    k/v (B,L,Hkv,D) whose absolute positions are kp (B,L), -1 = empty.
    qpos (B,Sq) holds the query positions (causality + window come from the
    position metadata alone, so ring and paged layouts share this path)."""
    o = attend_masked(cfg, q, k_all, v_all, kp, qpos, window=window)
    return out_project(params, o)


def decode_attention(params, cfg: ModelConfig, x: jax.Array, cache: KVCache,
                     position: jax.Array, *, window: Optional[int] = None):
    """One decode step.  x (B,1,d); position int32 — a scalar (all rows at
    the same index, the single-request path) or a (B,) vector of PER-ROW
    indices (the serve engine's continuous-batching path, where every slot
    advances its own counter).

    Returns (out (B,1,d), new_cache).
    """
    B = x.shape[0]
    pos = jnp.broadcast_to(
        jnp.atleast_1d(jnp.asarray(position, jnp.int32)), (B,))
    q, k_new, v_new = qkv_project(params, cfg, x, pos[:, None])
    L = cache.k.shape[1]
    slot = pos % L                                              # (B,) ring slots
    bidx = jnp.arange(B)
    new_k = cache.k.at[bidx, slot].set(k_new[:, 0].astype(cache.k.dtype))
    new_v = cache.v.at[bidx, slot].set(v_new[:, 0].astype(cache.v.dtype))
    new_pos = cache.pos.at[bidx, slot].set(pos)
    new_cache = KVCache(new_k, new_v, new_pos)
    out = attend_cached(params, cfg, q, new_cache.k, new_cache.v,
                        new_cache.pos, pos[:, None], window=window)
    return out, new_cache


# ---------------------------------------------------------------------------
# Paged KV cache: a pool of fixed-size pages shared by all request slots.
#
# Physical layout is (num_pages, page_size, Hkv, D); a slot owns an ordered
# page row (pages_per_slot,) of physical page ids (-1 = unassigned) mapping
# logical token index i -> pool[row[i // page_size], i % page_size].  Slot
# count is therefore decoupled from cache length: the pool is sized to live
# tokens, not slots * max_len.  Invalid writes are redirected to the
# out-of-bounds page id ``num_pages`` and dropped by XLA (mode="drop");
# gathers of unassigned pages fill with position -1, which the shared
# position mask in ``attend_cached`` already treats as empty.
# ---------------------------------------------------------------------------
class PagedKVCache(NamedTuple):
    k: jax.Array          # (P, page_size, Hkv, D)
    v: jax.Array          # (P, page_size, Hkv, D)
    pos: jax.Array        # (P, page_size) absolute position per entry, -1 = empty


def paged_kv_cache_axes():
    # the page-size axis reuses the "cache_seq" rule so the
    # cache_needs_seq_shard branch (ffn-mode / indivisible kv_heads archs)
    # shards the pool over "model" exactly like the contiguous ring does
    return PagedKVCache(
        k=("pages", "cache_seq", "kv_heads", "head_dim"),
        v=("pages", "cache_seq", "kv_heads", "head_dim"),
        pos=("pages", "cache_seq"),
    )


def init_paged_kv_cache(num_pages: int, page_size: int, hkv: int, dh: int,
                        dtype=jnp.bfloat16) -> PagedKVCache:
    return PagedKVCache(
        k=jnp.zeros((num_pages, page_size, hkv, dh), dtype),
        v=jnp.zeros((num_pages, page_size, hkv, dh), dtype),
        pos=jnp.full((num_pages, page_size), -1, jnp.int32),
    )


def gather_pages(cache: PagedKVCache, page_rows: jax.Array):
    """page_rows (B, n) -> (k (B, n*ps, Hkv, D), v, pos (B, n*ps)).

    Unassigned entries (page id -1) gather as empty: k/v fill 0 and pos
    fills -1, so downstream masking needs no page-validity plumbing."""
    P, ps, hkv, dh = cache.k.shape
    B, n = page_rows.shape
    safe = jnp.where(page_rows >= 0, page_rows, P)              # P = out of bounds
    k = jnp.take(cache.k, safe, axis=0, mode="fill", fill_value=0)
    v = jnp.take(cache.v, safe, axis=0, mode="fill", fill_value=0)
    pos = jnp.take(cache.pos, safe, axis=0, mode="fill", fill_value=-1)
    return (k.reshape(B, n * ps, hkv, dh), v.reshape(B, n * ps, hkv, dh),
            pos.reshape(B, n * ps))


def _page_coords(page_rows: jax.Array, logical: jax.Array, ps: int, P: int,
                 extra_ok=None):
    """Map logical token indices to (physical page, offset) with invalid
    indices redirected to the droppable out-of-bounds page id ``P``.
    page_rows (..., n) and logical (...,) share leading dims."""
    n = page_rows.shape[-1]
    lp = logical // ps
    ok = (logical >= 0) & (lp < n)
    if extra_ok is not None:
        ok = ok & extra_ok
    phys = jnp.take_along_axis(page_rows, jnp.clip(lp, 0, n - 1)[..., None],
                               axis=-1)[..., 0]
    phys = jnp.where(ok & (phys >= 0), phys, P)
    return phys, logical % ps, ok


def paged_attend(params, cfg: ModelConfig, q: jax.Array,
                 cache: PagedKVCache, page_rows: jax.Array,
                 qpos: jax.Array, *,
                 window: Optional[int] = None) -> jax.Array:
    """Attend q (B,T,Hq,D) against the page pool through slot page tables
    page_rows (B,n) and project out.  Dispatches to the fused Pallas
    kernel (``kernels.paged_attn``) when ``use_paged_attn_kernel()`` says
    so — the TPU fast path, no gathered cache copy — and otherwise to the
    lax fallback (``gather_pages`` + ``attend_masked``).  Both paths see
    the same position metadata, so masking semantics are identical."""
    if use_paged_attn_kernel():
        o = paged_attention_fused(
            q, cache.k, cache.v, cache.pos, page_rows, qpos,
            window=int(window) if window else 0,
            softcap=float(cfg.attn_softcap) if cfg.attn_softcap else 0.0)
    else:
        k_all, v_all, kp = gather_pages(cache, page_rows)
        o = attend_masked(cfg, q, k_all, v_all, kp, qpos, window=window)
    return out_project(params, o)


def paged_fill_from_prefill(pool: PagedKVCache, ring: KVCache,
                            page_row: jax.Array) -> PagedKVCache:
    """Write a single-request contiguous prefill cache ``ring`` (batch 1,
    ring layout with absolute positions) into the slot's pages of ``pool``
    — the whole-prompt paged insert reuses ``tfm.prefill`` unchanged."""
    P, ps = pool.k.shape[0], pool.k.shape[1]
    pos = ring.pos[0]                                           # (L,) absolute, -1 empty
    rows = jnp.broadcast_to(page_row, (pos.shape[0],) + page_row.shape)
    phys, off, ok = _page_coords(rows, pos, ps, P)
    new_k = pool.k.at[phys, off].set(ring.k[0].astype(pool.k.dtype),
                                     mode="drop")
    new_v = pool.v.at[phys, off].set(ring.v[0].astype(pool.v.dtype),
                                     mode="drop")
    new_pos = pool.pos.at[phys, off].set(pos, mode="drop")
    return PagedKVCache(new_k, new_v, new_pos)


def paged_decode_attention(params, cfg: ModelConfig, x: jax.Array,
                           cache: PagedKVCache, page_rows: jax.Array,
                           position: jax.Array, *,
                           window: Optional[int] = None,
                           active: Optional[jax.Array] = None):
    """One decode step against the page pool.  x (B,1,d); page_rows (B,n)
    per-slot page tables; position (B,) per-row write index; ``active``
    (B,) bool — inactive rows (free slots, or slots mid-chunked-prefill)
    have their writes dropped so they can never clobber a live page.

    Returns (out (B,1,d), new_cache)."""
    B = x.shape[0]
    pos = jnp.broadcast_to(
        jnp.atleast_1d(jnp.asarray(position, jnp.int32)), (B,))
    q, k_new, v_new = qkv_project(params, cfg, x, pos[:, None])
    P, ps = cache.k.shape[0], cache.k.shape[1]
    phys, off, ok = _page_coords(page_rows, pos, ps, P, extra_ok=active)
    new_k = cache.k.at[phys, off].set(k_new[:, 0].astype(cache.k.dtype),
                                      mode="drop")
    new_v = cache.v.at[phys, off].set(v_new[:, 0].astype(cache.v.dtype),
                                      mode="drop")
    new_pos = cache.pos.at[phys, off].set(pos, mode="drop")
    new_cache = PagedKVCache(new_k, new_v, new_pos)
    out = paged_attend(params, cfg, q, new_cache, page_rows, pos[:, None],
                       window=window)
    return out, new_cache


def paged_multitok_attention(params, cfg: ModelConfig, x: jax.Array,
                             cache: PagedKVCache, page_rows: jax.Array,
                             position: jax.Array, *,
                             window: Optional[int] = None,
                             active: Optional[jax.Array] = None):
    """Multi-token paged attention for ALL slots at once: x (B,T,d) holds T
    consecutive tokens per slot, row b starting at absolute ``position[b]``.
    Every token's K/V is scattered into its slot's pages (inactive rows'
    writes are dropped), then each query attends against its slot's whole
    gathered cache — earlier context plus the preceding tokens of its own
    run, with intra-run causality enforced by the shared position mask.

    This is both the chunked-prefill path (B=1, a prompt chunk) and the
    draft-verification path (one query per proposed token): a query at
    position p never sees entries with pos > p, so cache entries written
    by later-rejected draft tokens are invisible to every surviving query
    and are overwritten before the real sequence reaches them.

    Returns (out (B,T,d), new_cache)."""
    B, T, _ = x.shape
    pos = jnp.broadcast_to(
        jnp.atleast_1d(jnp.asarray(position, jnp.int32)), (B,))
    qpos = pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]  # (B,T)
    q, k_new, v_new = qkv_project(params, cfg, x, qpos)
    P, ps = cache.k.shape[0], cache.k.shape[1]
    rows = jnp.broadcast_to(page_rows[:, None, :],
                            (B, T, page_rows.shape[-1]))
    extra = None if active is None else active[:, None]
    phys, off, ok = _page_coords(rows, qpos, ps, P, extra_ok=extra)
    new_k = cache.k.at[phys, off].set(k_new.astype(cache.k.dtype),
                                      mode="drop")
    new_v = cache.v.at[phys, off].set(v_new.astype(cache.v.dtype),
                                      mode="drop")
    new_pos = cache.pos.at[phys, off].set(qpos, mode="drop")
    new_cache = PagedKVCache(new_k, new_v, new_pos)
    out = paged_attend(params, cfg, q, new_cache, page_rows, qpos,
                       window=window)
    return out, new_cache


def paged_prefill_attention(params, cfg: ModelConfig, x: jax.Array,
                            cache: PagedKVCache, page_row: jax.Array,
                            pos_start: jax.Array, *,
                            window: Optional[int] = None):
    """Chunked-prefill attention for ONE request slot.  x (1,C,d) is one
    prompt chunk starting at absolute position ``pos_start``; a batch-1
    view of :func:`paged_multitok_attention`.

    Returns (out (1,C,d), new_cache)."""
    return paged_multitok_attention(
        params, cfg, x, cache, page_row[None],
        jnp.reshape(jnp.asarray(pos_start, jnp.int32), (1,)), window=window)


# ---------------------------------------------------------------------------
# Full-sequence layer entry point (train / prefill)
# ---------------------------------------------------------------------------
def attention_block(params, cfg: ModelConfig, x: jax.Array, *, kind: str,
                    positions: Optional[jax.Array] = None,
                    return_kv: bool = False):
    """x (B,S,d) -> (B,S,d); kind in {global, local}.

    Global attention picks its execution path by use:
      * training / encoder forward (return_kv=False) -> blockq_attention
        (checkpointed Q blocks: autodiff-memory-safe);
      * prefill (return_kv=True, no grad) -> flash_attention (online-softmax
        scan: O(block) live memory).
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    q, k, v = qkv_project(params, cfg, x, positions)
    if kind == "local":
        o = local_attention(q, k, v, window=cfg.window, causal=cfg.causal,
                            softcap_val=cfg.attn_softcap)
    elif return_kv:
        o = flash_attention(q, k, v, causal=cfg.causal,
                            softcap_val=cfg.attn_softcap)
    else:
        o = blockq_attention(q, k, v, causal=cfg.causal,
                             softcap_val=cfg.attn_softcap)
    out = out_project(params, o)
    if return_kv:
        return out, (k, v)
    return out
