"""Mamba-2 SSD block [arXiv:2405.21060] — chunked dual form.

TPU adaptation (DESIGN.md §2/§6): the SSD *dual form* is used for
training/prefill because it turns the selective-scan into chunk-local
matmuls (MXU-friendly) plus a tiny O(S/Q) recurrence over chunk states —
the GPU paper's warp-level scan has no TPU analogue and is not needed.
Decode is the O(1) recurrent form.

Shapes: x (B,S,H,P) heads/headdim, B/C (B,S,G,N) groups/state,
dt (B,S,H), A (H,) negative decay.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParamFactory, constrain
from repro.models.layers import apply_norm, norm_params


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------
def ssm_params(mk: ParamFactory, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    H = s.num_heads(d)
    G, N = s.ngroups, s.state_dim
    conv_dim = di + 2 * G * N
    return {
        "w_in_x": mk((d, di), ("embed", "inner")),
        "w_in_z": mk((d, di), ("embed", "inner")),
        "w_in_B": mk((d, G * N), ("embed", "state")),
        "w_in_C": mk((d, G * N), ("embed", "state")),
        "w_in_dt": mk((d, H), ("embed", "heads")),
        "dt_bias": mk((H,), ("heads",), init="zeros"),
        "A_log": mk((H,), ("heads",), init="uniform", scale=1.0),
        "D": mk((H,), ("heads",), init="ones"),
        "conv_w": mk((s.conv_width, conv_dim), ("conv", "inner")),
        "conv_b": mk((conv_dim,), ("inner",), init="zeros"),
        "out_norm": norm_params(mk, "rmsnorm", di),
        "w_out": mk((di, d), ("inner", "embed")),
    }


class SSMState(NamedTuple):
    h: jax.Array          # (B, H, P, N) recurrent state
    conv: jax.Array       # (B, conv_width-1, conv_dim) conv tail


def ssm_state_axes():
    return SSMState(h=("batch", "heads", None, "state"),
                    conv=("batch", None, "inner"))


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SSMState:
    s = cfg.ssm
    d = cfg.d_model
    H, P, N = s.num_heads(d), s.head_dim, s.state_dim
    conv_dim = s.d_inner(d) + 2 * s.ngroups * N
    return SSMState(
        h=jnp.zeros((batch, H, P, N), dtype),
        conv=jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
    )


# ---------------------------------------------------------------------------
# Projections + causal conv shared by both paths
# ---------------------------------------------------------------------------
def _project(params, cfg: ModelConfig, x: jax.Array):
    """x (B,S,d) -> z, xBC (pre-conv), dt."""
    z = jnp.einsum("bsd,de->bse", x, params["w_in_z"].astype(x.dtype))
    xb = jnp.einsum("bsd,de->bse", x, params["w_in_x"].astype(x.dtype))
    Bp = jnp.einsum("bsd,dn->bsn", x, params["w_in_B"].astype(x.dtype))
    Cp = jnp.einsum("bsd,dn->bsn", x, params["w_in_C"].astype(x.dtype))
    dt = jnp.einsum("bsd,dh->bsh", x, params["w_in_dt"].astype(x.dtype))
    xBC = jnp.concatenate([xb, Bp, Cp], axis=-1)
    return z, xBC, dt


def _causal_conv(params, cfg: ModelConfig, xBC: jax.Array,
                 tail: jax.Array | None = None):
    """Depthwise causal conv width K.  xBC (B,S,C); tail (B,K-1,C) or None."""
    K = cfg.ssm.conv_width
    w = params["conv_w"].astype(xBC.dtype)                      # (K, C)
    if tail is None:
        pad = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = tail.astype(xBC.dtype)
    full = jnp.concatenate([pad, xBC], axis=1)                  # (B, S+K-1, C)
    out = sum(full[:, i:i + xBC.shape[1]] * w[i] for i in range(K))
    out = out + params["conv_b"].astype(xBC.dtype)
    new_tail = full[:, -( K - 1):] if K > 1 else pad[:, :0]
    return jax.nn.silu(out), new_tail


def _split_xbc(cfg: ModelConfig, xBC: jax.Array):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    GN = s.ngroups * s.state_dim
    xh = xBC[..., :di]
    Bm = xBC[..., di:di + GN]
    Cm = xBC[..., di + GN:]
    return xh, Bm, Cm


# ---------------------------------------------------------------------------
# SSD chunked dual form (train / prefill)
# ---------------------------------------------------------------------------
def _segsum(a: jax.Array) -> jax.Array:
    """a (..., Q) -> (..., Q, Q) with out[i,j] = sum_{k=j+1..i} a_k (i>=j)."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]                   # sum_{j+1..i}
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(xh, dt, A, Bm, Cm, D, chunk: int):
    """SSD dual form.  xh (B,S,H,P); dt (B,S,H) post-softplus; A (H,) < 0;
    Bm/Cm (B,S,G,N); D (H,).  Returns y (B,S,H,P) and final state (B,H,P,N).
    """
    Bsz, S0, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, S0)
    # pad S to a chunk multiple; padded steps have dt=0 -> decay 1, no input,
    # so they neither change the state nor the (discarded) outputs.
    pad = (-S0) % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S = S0 + pad
    nc = S // Q
    rep = H // G

    # expand groups to heads
    Bh = jnp.repeat(Bm, rep, axis=2)                            # (B,S,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2)

    # chunked views
    xc = xh.reshape(Bsz, nc, Q, H, P)
    dtc = dt.reshape(Bsz, nc, Q, H)
    Bc = Bh.reshape(Bsz, nc, Q, H, N)
    Cc = Ch.reshape(Bsz, nc, Q, H, N)

    dA = (dtc * A[None, None, None, :]).astype(jnp.float32)     # (B,nc,Q,H) log decay
    dA = dA.transpose(0, 1, 3, 2)                               # (B,nc,H,Q)
    dA_cs = jnp.cumsum(dA, axis=-1)                             # within-chunk cumsum

    # 1. intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(dA))                                    # (B,nc,H,Q,Q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc).astype(jnp.float32)
    M = scores * L
    xdt = xc * dtc[..., None]                                   # dt-weighted input
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", M.astype(xh.dtype), xdt)

    # 2. per-chunk output states: decay from position to end of chunk
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)             # (B,nc,H,Q)
    states = jnp.einsum("bcqhn,bchq,bcqhp->bchpn",
                        Bc, decay_states.astype(xh.dtype), xdt)  # (B,nc,H,P,N)

    # 3. inter-chunk recurrence over chunk states
    chunk_decay = jnp.exp(dA_cs[..., -1])                       # (B,nc,H) total decay
    def body(h, inp):
        st, dec = inp                                           # (B,H,P,N), (B,H)
        h_new = h * dec[..., None, None].astype(h.dtype) + st
        return h_new, h                                         # emit PREVIOUS state
    h0 = jnp.zeros((Bsz, xh.shape[2], P, N), xh.dtype)
    hT, h_prev = jax.lax.scan(
        body, h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                    # (B,nc,H,P,N) state entering chunk

    # 4. state -> output contribution within each chunk
    decay_in = jnp.exp(dA_cs)                                   # (B,nc,H,Q) decay from chunk start
    y_off = jnp.einsum("bcqhn,bchpn,bchq->bcqhp",
                       Cc, h_prev, decay_in.astype(xh.dtype))

    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    y = y + xh * D[None, None, :, None].astype(xh.dtype)
    return y[:, :S0], hT


def ssm_block(params, cfg: ModelConfig, x: jax.Array, *,
              return_state: bool = False):
    """Full-sequence Mamba-2 block.  x (B,S,d) -> (B,S,d)."""
    s = cfg.ssm
    z, xBC, dt = _project(params, cfg, x)
    xBC, tail = _causal_conv(params, cfg, xBC)
    xh, Bm, Cm = _split_xbc(cfg, xBC)
    Bsz, S = x.shape[0], x.shape[1]
    H, P = s.num_heads(cfg.d_model), s.head_dim
    xh = xh.reshape(Bsz, S, H, P)
    xh = constrain(xh, ("batch", "seq", "heads", None))
    Bm = Bm.reshape(Bsz, S, s.ngroups, s.state_dim)
    Cm = Cm.reshape(Bsz, S, s.ngroups, s.state_dim)
    dt = jax.nn.softplus(dt + params["dt_bias"].astype(dt.dtype))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, hT = ssd_chunked(xh, dt, A, Bm, Cm,
                        params["D"].astype(x.dtype), s.chunk_size)
    y = y.reshape(Bsz, S, -1)
    y = apply_norm(params["out_norm"], "rmsnorm", y * jax.nn.silu(z))
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(x.dtype))
    out = constrain(out, ("batch", "seq", "embed"))
    if return_state:
        return out, SSMState(h=hT.astype(jnp.float32), conv=tail.astype(jnp.float32))
    return out


def ssm_decode_step(params, cfg: ModelConfig, x: jax.Array, state: SSMState):
    """One-token recurrent step.  x (B,1,d) -> (out (B,1,d), new state)."""
    s = cfg.ssm
    z, xBC, dt = _project(params, cfg, x)                       # (B,1,...)
    xBC, new_tail = _causal_conv(params, cfg, xBC, tail=state.conv)
    xh, Bm, Cm = _split_xbc(cfg, xBC)
    Bsz = x.shape[0]
    H, P, N, G = (s.num_heads(cfg.d_model), s.head_dim, s.state_dim, s.ngroups)
    xh = xh.reshape(Bsz, H, P)
    Bm = Bm.reshape(Bsz, G, N)
    Cm = Cm.reshape(Bsz, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)                            # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)
    dt1 = jax.nn.softplus(dt[:, 0] + params["dt_bias"].astype(dt.dtype))  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))           # (H,)
    dA = jnp.exp(dt1.astype(jnp.float32) * A[None, :])          # (B,H)
    h = state.h * dA[..., None, None]
    h = h + jnp.einsum("bhp,bhn->bhpn", (xh * dt1[..., None]).astype(h.dtype),
                       Bh.astype(h.dtype))
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch.astype(h.dtype))
    y = y + xh.astype(h.dtype) * params["D"].astype(h.dtype)[None, :, None]
    y = y.reshape(Bsz, 1, H * P).astype(x.dtype)
    y = apply_norm(params["out_norm"], "rmsnorm", y * jax.nn.silu(z))
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(x.dtype))
    return out, SSMState(h=h, conv=new_tail.astype(jnp.float32))
