"""RG-LRU recurrent block (RecurrentGemma / Griffin) [arXiv:2402.19427].

Block = (x-branch: linear -> causal conv -> RG-LRU) gated by
(y-branch: linear -> GELU), then output projection.

RG-LRU:  r_t = sigma(W_a u_t + b_a)         recurrence gate
         i_t = sigma(W_x u_t + b_x)         input gate
         a_t = exp(-c * softplus(Lambda) * r_t)
         h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Training/prefill uses ``jax.lax.associative_scan`` over the sequence
(TPU-friendly log-depth scan); decode is the O(1) recurrent step.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParamFactory, constrain


def rglru_params(mk: ParamFactory, cfg: ModelConfig):
    g = cfg.rglru
    d = cfg.d_model
    w = g.lru_width or d
    return {
        "w_x": mk((d, w), ("embed", "inner")),
        "w_y": mk((d, w), ("embed", "inner")),
        "conv_w": mk((g.conv_width, w), ("conv", "inner")),
        "conv_b": mk((w,), ("inner",), init="zeros"),
        "wa": mk((w, w), ("inner", "inner")),
        "ba": mk((w,), ("inner",), init="zeros"),
        "wi": mk((w, w), ("inner", "inner")),
        "bi": mk((w,), ("inner",), init="zeros"),
        "lam": mk((w,), ("inner",), init="uniform", scale=1.0),
        "w_out": mk((w, d), ("inner", "embed")),
    }


class RGLRUState(NamedTuple):
    h: jax.Array          # (B, W) recurrent state
    conv: jax.Array       # (B, K-1, W) conv tail


def rglru_state_axes():
    return RGLRUState(h=("batch", "inner"), conv=("batch", None, "inner"))


def init_rglru_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> RGLRUState:
    g = cfg.rglru
    w = g.lru_width or cfg.d_model
    return RGLRUState(h=jnp.zeros((batch, w), dtype),
                      conv=jnp.zeros((batch, g.conv_width - 1, w), dtype))


def _conv(params, cfg: ModelConfig, u: jax.Array, tail=None):
    K = cfg.rglru.conv_width
    w = params["conv_w"].astype(u.dtype)
    if tail is None:
        pad = jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
    else:
        pad = tail.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)
    out = sum(full[:, i:i + u.shape[1]] * w[i] for i in range(K))
    new_tail = full[:, -(K - 1):] if K > 1 else pad[:, :0]
    return out + params["conv_b"].astype(u.dtype), new_tail


def _gates(params, cfg: ModelConfig, u: jax.Array):
    """u (B,S,W) -> (a (log-space fp32), gated input b) per step."""
    c = cfg.rglru.c_constant
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", u, params["wa"].astype(u.dtype))
                       + params["ba"].astype(u.dtype)).astype(jnp.float32)
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", u, params["wi"].astype(u.dtype))
                       + params["bi"].astype(u.dtype)).astype(jnp.float32)
    log_a = -c * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u.astype(jnp.float32))
    return a, b


def rglru_block(params, cfg: ModelConfig, x: jax.Array, *,
                return_state: bool = False):
    """Full-sequence Griffin recurrent block.  x (B,S,d) -> (B,S,d)."""
    u = jnp.einsum("bsd,dw->bsw", x, params["w_x"].astype(x.dtype))
    y_gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, params["w_y"].astype(x.dtype)))
    u, tail = _conv(params, cfg, u)
    u = constrain(u, ("batch", "seq", "inner"))
    a, b = _gates(params, cfg, u)

    # linear recurrence h_t = a_t h_{t-1} + b_t via associative scan over S
    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2
    a_sc, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(x.dtype)
    out = jnp.einsum("bsw,wd->bsd", h * y_gate,
                     params["w_out"].astype(x.dtype))
    out = constrain(out, ("batch", "seq", "embed"))
    if return_state:
        return out, RGLRUState(h=h[:, -1].astype(jnp.float32),
                               conv=tail.astype(jnp.float32))
    return out


def rglru_decode_step(params, cfg: ModelConfig, x: jax.Array,
                      state: RGLRUState):
    """One-token step.  x (B,1,d) -> (out (B,1,d), new state)."""
    u = jnp.einsum("bsd,dw->bsw", x, params["w_x"].astype(x.dtype))
    y_gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, params["w_y"].astype(x.dtype)))
    u, new_tail = _conv(params, cfg, u, tail=state.conv)
    a, b = _gates(params, cfg, u)                               # (B,1,W) fp32
    h = a[:, 0] * state.h + b[:, 0]
    out = jnp.einsum("bsw,wd->bsd", (h[:, None].astype(x.dtype) * y_gate),
                     params["w_out"].astype(x.dtype))
    return out, RGLRUState(h=h, conv=new_tail.astype(jnp.float32))
