"""Gated MLP (SwiGLU / GEGLU) with tensor-parallel ffn sharding."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParamFactory, constrain


def mlp_params(mk: ParamFactory, d_model: int, d_ff: int):
    return {
        "w_gate": mk((d_model, d_ff), ("embed", "ffn")),
        "w_up": mk((d_model, d_ff), ("embed", "ffn")),
        "w_down": mk((d_ff, d_model), ("ffn", "embed")),
    }


def _act(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown act '{kind}'")


def mlp_block(params, cfg_or_act, x: jax.Array) -> jax.Array:
    """x (B,S,d) -> (B,S,d).  Accepts a ModelConfig or an act-name string."""
    act = cfg_or_act.act if isinstance(cfg_or_act, ModelConfig) else cfg_or_act
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(x.dtype))
    h = _act(g, act) * u
    h = constrain(h, ("batch", "seq", "ffn"))
    out = jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(x.dtype))
    return constrain(out, ("batch", "seq", "embed"))
