"""Shared layers: norms, rotary embeddings, embedding tables, softcap."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ParamFactory, constrain


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def norm_params(mk: ParamFactory, kind: str, dim: int):
    if kind == "rmsnorm":
        return {"scale": mk((dim,), ("embed",), init="ones")}
    if kind == "layernorm":
        return {"scale": mk((dim,), ("embed",), init="ones"),
                "bias": mk((dim,), ("embed",), init="zeros")}
    if kind == "nonparam_ln":      # OLMo: no learnable affine
        return {}
    raise ValueError(f"unknown norm '{kind}'")


def apply_norm(params, kind: str, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + eps)
        # gemma-style (1 + scale) is not used; plain scale
        y = y * params["scale"].astype(jnp.float32)
    elif kind in ("layernorm", "nonparam_ln"):
        mean = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mean) * jax.lax.rsqrt(var + eps)
        if kind == "layernorm":
            y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:
        raise ValueError(kind)
    return y.astype(dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """gemma2 logit soft-capping: cap * tanh(x / cap)."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, D) with positions (..., S) or (S,)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))          # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, dim: int, offset: int = 0) -> jax.Array:
    """Fixed sinusoidal table (used as the HuBERT conv-pos-emb stand-in)."""
    pos = np.arange(offset, offset + seq_len, dtype=np.float32)[:, None]
    i = np.arange(dim // 2, dtype=np.float32)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    table = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(table)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def embed_params(mk: ParamFactory, vocab: int, d_model: int, tie: bool,
                 padded_vocab: Optional[int] = None):
    """``padded_vocab`` (>= vocab, multiple of the model-axis size) lets the
    embedding shard on the model axis even for odd vocab sizes; the padded
    logit columns are masked in ``unembed``."""
    pv = padded_vocab or vocab
    p = {"embedding": mk((pv, d_model), ("vocab", "embed"),
                         init="embed", scale=0.02)}
    if not tie:
        p["unembed"] = mk((d_model, pv), ("embed", "vocab"),
                          init="embed", scale=0.02)
    return p


def embed(params, tokens: jax.Array, *, scale: bool, d_model: int,
          dtype) -> jax.Array:
    x = jnp.take(params["embedding"], tokens, axis=0).astype(dtype)
    if scale:
        x = x * jnp.asarray(np.sqrt(d_model), dtype)
    return constrain(x, ("batch", "seq", "embed"))


def unembed(params, x: jax.Array, *, tie: bool, cap: float = 0.0,
            real_vocab: Optional[int] = None) -> jax.Array:
    if tie:
        logits = jnp.einsum("...d,vd->...v", x, params["embedding"].astype(x.dtype))
    else:
        logits = jnp.einsum("...d,dv->...v", x, params["unembed"].astype(x.dtype))
    logits = softcap(logits.astype(jnp.float32), cap)
    pv = logits.shape[-1]
    if real_vocab is not None and real_vocab < pv:
        # mask vocab-padding columns so softmax/argmax never select them
        col = jnp.arange(pv)
        logits = jnp.where(col[None, :] < real_vocab
                           if logits.ndim == 2 else
                           col[None, None, :] < real_vocab,
                           logits, -1e30)
    return constrain(logits, ("batch", "seq", "vocab"))


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy in fp32. logits (..., V), targets (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
