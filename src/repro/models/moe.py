"""Mixture-of-Experts FFN with capacity-based gather/scatter dispatch.

Expert-parallel design (DESIGN.md §4/§5): the expert dim of the stacked
expert weights is sharded over the "model" mesh axis (the paper's
head-partitioning generalized to experts).  Dispatch is *per batch row*
(``vmap``-style gathers along the token axis) so the batch axis stays
sharded over "data"/"pod" and GSPMD never moves tokens across data shards;
combining contracts over the expert axis, which lowers to the expected
expert-parallel all-reduce over "model".

Unlike one-hot einsum dispatch (O(tokens^2) FLOPs), gather/scatter dispatch
keeps compiled FLOPs at the *active* compute: tokens x top_k x d x ff.
Dropped-token handling follows the standard capacity-factor scheme.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoEConfig
from repro.distributed.sharding import ParamFactory, constrain
from repro.models.mlp import _act, mlp_block, mlp_params

try:                                  # newer jax: top-level export
    from jax import shard_map
except ImportError:                   # jax 0.4.x/0.5.x
    from jax.experimental.shard_map import shard_map
# the replication-check kwarg was renamed check_rep -> check_vma
# independently of where shard_map is exported, so probe the signature
import inspect as _inspect
_SHARD_MAP_KW = (
    {"check_vma": False}
    if "check_vma" in _inspect.signature(shard_map).parameters
    else {"check_rep": False})


def moe_params(mk: ParamFactory, cfg: ModelConfig):
    m = cfg.moe
    d = cfg.d_model
    p = {
        "router": mk((d, m.num_experts), ("embed", "experts"), scale=0.02),
        "w_gate": mk((m.num_experts, d, m.d_ff_expert), ("experts", "embed", "ffn")),
        "w_up": mk((m.num_experts, d, m.d_ff_expert), ("experts", "embed", "ffn")),
        "w_down": mk((m.num_experts, m.d_ff_expert, d), ("experts", "ffn", "embed")),
    }
    if m.num_shared:
        d_sh = m.d_ff_shared or m.d_ff_expert * m.num_shared
        p["shared"] = mlp_params(mk, d, d_sh)
    return p


def capacity(seq_len: int, m: MoEConfig, factor: float = 1.25) -> int:
    """Per-row expert capacity C = ceil(S * top_k / E * factor)."""
    c = int(np.ceil(seq_len * m.top_k / m.num_experts * factor))
    return max(c, 1)


def route(router_w: jax.Array, x: jax.Array, m: MoEConfig):
    """Router in fp32.  x (B,S,d) -> (probs (B,S,E), topk_idx (B,S,K), topk_w (B,S,K))."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = jax.lax.top_k(probs, m.top_k)
    # deepseek-style: renormalize the selected weights
    topk_w = topk_w / jnp.maximum(jnp.sum(topk_w, axis=-1, keepdims=True), 1e-9)
    return probs, topk_idx, topk_w


def load_balance_loss(probs: jax.Array, topk_idx: jax.Array, m: MoEConfig) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * P_e."""
    E = m.num_experts
    one_hot = jax.nn.one_hot(topk_idx, E, dtype=jnp.float32)    # (B,S,K,E)
    f = jnp.mean(jnp.sum(one_hot, axis=2), axis=(0, 1))         # fraction routed
    p = jnp.mean(probs, axis=(0, 1))
    return E * jnp.sum(f * p) / m.top_k


def _dispatch_indices(topk_idx: jax.Array, m: MoEConfig, cap: int):
    """Build per-row (E, C) token indices + validity from (S, K) assignments.

    Position-in-expert via cumsum over the flattened (S*K) assignment
    stream; tokens beyond capacity are dropped (standard).
    Returns (idx (E,C) int32 token ids, valid (E,C) bool, keep (S,K) bool,
    slot (S,K) int32).
    """
    S, K = topk_idx.shape
    E = m.num_experts
    flat_e = topk_idx.reshape(-1)                               # (S*K,)
    one_hot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # (S*K, E)
    pos = jnp.cumsum(one_hot, axis=0) - 1                       # position within expert
    slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]  # (S*K,)
    keep = slot < cap
    # scatter token ids into (E, C)
    tok = jnp.arange(S, dtype=jnp.int32).repeat(K)              # (S*K,)
    e_idx = jnp.where(keep, flat_e, E)                          # overflow bucket
    s_idx = jnp.where(keep, slot, 0)
    idx = jnp.zeros((E + 1, cap), jnp.int32).at[e_idx, s_idx].set(tok)
    valid = jnp.zeros((E + 1, cap), jnp.bool_).at[e_idx, s_idx].set(keep)
    return idx[:E], valid[:E], keep.reshape(S, K), slot.reshape(S, K)


def moe_block_auto(params, cfg: ModelConfig, x: jax.Array):
    """Dispatcher: expert-parallel shard_map combine when a mesh context is
    active and experts divide the model axis (the §Perf-optimized path),
    else the pure-pjit gather/scatter path."""
    import os
    from repro.distributed import sharding as shd
    ctx = getattr(shd._CTX, "val", None)
    if ctx is not None and os.environ.get("REPRO_MOE_SHARDMAP", "1") == "1":
        mesh, rules = ctx
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        model_ways = sizes.get("model", 1)
        if rules.get("experts") == "model" \
                and cfg.moe.num_experts % model_ways == 0:
            return moe_block_sharded(params, cfg, x, mesh)
    return moe_block(params, cfg, x)


def moe_block_sharded(params, cfg: ModelConfig, x: jax.Array, mesh):
    """Expert-parallel MoE with a LOCAL combine (beyond-paper §Perf fix).

    The pure-pjit path's scatter-add combine has data-dependent indices, so
    GSPMD replicates the full global batch and emits ~(B_global,S,d) fp32
    all-reduces per layer.  Here each model shard dispatches to its local
    E/ways experts, scatter-adds the weighted outputs into a LOCAL
    (B_loc,S,d) partial, and one bf16 ``psum`` over "model" combines —
    exactly the paper's spatial->temporal head hand-off, expert-parallel.
    """
    m = cfg.moe
    from jax.sharding import PartitionSpec as P
    axis_names = mesh.axis_names
    batch_ax = tuple(a for a in ("pod", "data") if a in axis_names)
    batch_ax = batch_ax if len(batch_ax) > 1 else (batch_ax[0] if batch_ax else None)
    xspec = P(batch_ax, None, None)
    wspec = {
        "router": P(None, None),
        "w_gate": P("model", None, None),
        "w_up": P("model", None, None),
        "w_down": P("model", None, None),
    }
    if m.num_shared:
        wspec["shared"] = jax.tree.map(lambda _: P(None, None),
                                       params["shared"])
    model_ways = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    e_local = m.num_experts // model_ways

    def local_fn(p, xl):
        from repro.distributed.sharding import suspend_logical_sharding
        with suspend_logical_sharding():
            return _local_moe(p, xl)

    def _local_moe(p, xl):
        B, S, d = xl.shape
        cap = capacity(S, m, m.capacity_factor)
        probs, topk_idx, topk_w = route(p["router"], xl, m)
        aux = load_balance_loss(probs, topk_idx, m)
        idx, valid, keep, slot = jax.vmap(
            lambda ti: _dispatch_indices(ti, m, cap))(topk_idx)  # (B,E,C)
        # slice this shard's experts
        shard = jax.lax.axis_index("model")
        e0 = shard * e_local
        idx_l = jax.lax.dynamic_slice_in_dim(idx, e0, e_local, axis=1)
        val_l = jax.lax.dynamic_slice_in_dim(valid, e0, e_local, axis=1)
        xe = jnp.take_along_axis(xl[:, None, :, :], idx_l[..., None],
                                 axis=2)                          # (B,El,C,d)
        xe = xe * val_l[..., None].astype(xl.dtype)
        g = jnp.einsum("becd,edf->becf", xe, p["w_gate"].astype(xl.dtype))
        u = jnp.einsum("becd,edf->becf", xe, p["w_up"].astype(xl.dtype))
        h = _act(g, cfg.act) * u
        ye = jnp.einsum("becf,efd->becd", h, p["w_down"].astype(xl.dtype))
        # local gate weights: (B,El,C)
        batch_idx = jnp.arange(B)[:, None, None]
        w_full = jnp.zeros((B, m.num_experts + 1, cap), jnp.float32)
        be = jnp.where(keep, topk_idx, m.num_experts)
        bs = jnp.where(keep, slot, 0)
        w_full = w_full.at[batch_idx, be, bs].add(jnp.where(keep, topk_w, 0.0))
        w_l = jax.lax.dynamic_slice_in_dim(
            w_full[:, :m.num_experts], e0, e_local, axis=1)
        ye = ye * w_l[..., None].astype(ye.dtype)
        # LOCAL scatter-add + one psum over the expert shards
        y = jnp.zeros((B, S, d), ye.dtype)
        y = y.at[batch_idx, idx_l, :].add(ye)
        y = jax.lax.psum(y, "model")
        # aux varies across data shards -> mean over the whole mesh so the
        # P() out_spec is sound
        aux = jax.lax.pmean(aux, tuple(mesh.axis_names))
        if m.num_shared:
            y = y + mlp_block(p["shared"], cfg.act, xl)
        return y, aux

    y, aux = shard_map(
        local_fn, mesh=mesh,
        in_specs=(wspec, xspec),
        out_specs=(xspec, P()),
        **_SHARD_MAP_KW,
    )(dict(params), x)
    return y, aux


def moe_block(params, cfg: ModelConfig, x: jax.Array):
    """x (B,S,d) -> (y (B,S,d), aux_loss scalar)."""
    m = cfg.moe
    B, S, d = x.shape
    cap = capacity(S, m, m.capacity_factor)
    probs, topk_idx, topk_w = route(params["router"], x, m)
    aux = load_balance_loss(probs, topk_idx, m)

    idx, valid, keep, slot = jax.vmap(
        lambda ti: _dispatch_indices(ti, m, cap))(topk_idx)     # (B,E,C) ...

    # gather tokens per expert: (B,E,C,d); batch stays sharded on data
    xe = jnp.take_along_axis(
        x[:, None, :, :], idx[..., None], axis=2)               # (B,E,C,d)
    xe = xe * valid[..., None].astype(x.dtype)
    xe = constrain(xe, ("batch", "experts", None, "embed"))

    g = jnp.einsum("becd,edf->becf", xe, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("becd,edf->becf", xe, params["w_up"].astype(x.dtype))
    h = _act(g, cfg.act) * u
    h = constrain(h, ("batch", "experts", None, "ffn"))
    ye = jnp.einsum("becf,efd->becd", h, params["w_down"].astype(x.dtype))

    # combine: weight each expert output and scatter-add back to tokens.
    # gate weight per (E,C) slot:
    w_ec = jnp.zeros((B, m.num_experts, cap), jnp.float32)
    be = jnp.where(keep, topk_idx, m.num_experts)               # (B,S,K)
    bs = jnp.where(keep, slot, 0)
    tokw = topk_w                                               # (B,S,K) fp32
    w_full = jnp.zeros((B, m.num_experts + 1, cap), jnp.float32)
    batch_idx = jnp.arange(B)[:, None, None]
    w_full = w_full.at[batch_idx, be, bs].add(
        jnp.where(keep, tokw, 0.0))
    w_ec = w_full[:, :m.num_experts]
    ye = ye * w_ec[..., None].astype(ye.dtype)

    # scatter-add (B,E,C,d) back to (B,S,d) by token index
    y = jnp.zeros((B, S, d), ye.dtype)
    y = y.at[batch_idx, idx, :].add(ye)                         # contracts E -> all-reduce over model
    y = constrain(y, ("batch", "seq", "embed"))

    if m.num_shared:
        y = y + mlp_block(params["shared"], cfg.act, x)
    return y, aux
