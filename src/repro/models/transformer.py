"""Generic architecture stack for all assigned families.

Layers are grouped into repetitions of ``cfg.layer_pattern`` and scanned
with ``jax.lax.scan`` (stacked params, small HLO even for 48-layer models);
``first_k_dense`` prefix layers and pattern remainders are unrolled.

Public API:
  init(cfg, key) / param_specs(cfg)
  forward(params, cfg, inputs, ...)            train/encoder forward
  lm_loss(params, cfg, batch)                  chunked-vocab LM loss
  prefill(params, cfg, inputs, max_len)        -> (last_logits, cache)
  decode_step(params, cfg, token_inputs, cache, position)
  init_cache(cfg, batch, max_len) / cache_specs(cfg, batch, max_len)
"""
from __future__ import annotations

import functools
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (
    ATTN_GLOBAL, ATTN_LOCAL, RECURRENT, SSM, ModelConfig,
)
from repro.core.gating import contribution_gate, gate_params
from repro.distributed.sharding import ParamFactory, constrain
from repro.models import attention as attn_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    apply_norm, cross_entropy, embed, embed_params, norm_params,
    sinusoidal_positions, softcap, unembed,
)

LayerKind = str


def _scan_unroll() -> bool:
    """Fully unroll layer/loss scans (dry-run accounting mode).

    XLA's cost_analysis counts a while-loop body ONCE regardless of trip
    count, so the dry-run sets REPRO_SCAN_UNROLL=1 to unroll the scans and
    make HLO FLOPs / collective-bytes reflect the whole program.
    """
    return os.environ.get("REPRO_SCAN_UNROLL", "0") == "1"


# ---------------------------------------------------------------------------
# Stack structure helpers
# ---------------------------------------------------------------------------
def stack_plan(cfg: ModelConfig):
    """Return (prefix_kinds, pattern, n_rep, suffix_kinds)."""
    kinds = cfg.layer_kinds()
    k = cfg.first_k_dense
    if k:
        assert len(set(cfg.layer_pattern)) == 1, \
            "first_k_dense requires a uniform layer pattern"
    prefix = kinds[:k]
    rest = kinds[k:]
    pat = cfg.layer_pattern
    n_rep = len(rest) // len(pat)
    suffix = rest[n_rep * len(pat):]
    return prefix, pat, n_rep, suffix


def _ffn_kind(cfg: ModelConfig, kind: LayerKind, *, in_prefix: bool) -> str:
    if kind == SSM:
        return "none"                # mamba block has no separate FFN
    if cfg.moe is not None and not in_prefix:
        return "moe"
    return "dense" if cfg.d_ff else "none"


def _layer_params(mk: ParamFactory, cfg: ModelConfig, kind: LayerKind,
                  ffn: str):
    d = cfg.d_model
    p: Dict[str, Any] = {"norm1": norm_params(mk, cfg.norm, d)}
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        p["attn"] = attn_mod.attn_params(mk, cfg)
    elif kind == RECURRENT:
        p["rec"] = rglru_mod.rglru_params(mk, cfg)
    elif kind == SSM:
        p["ssm"] = ssm_mod.ssm_params(mk, cfg)
    else:
        raise ValueError(kind)
    if cfg.post_norms:
        p["post_norm1"] = norm_params(mk, cfg.norm, d)
    if ffn != "none":
        p["norm2"] = norm_params(mk, cfg.norm, d)
        if ffn == "dense":
            p["ffn"] = mlp_mod.mlp_params(mk, d, cfg.d_ff)
        else:
            p["ffn"] = moe_mod.moe_params(mk, cfg)
        if cfg.post_norms:
            p["post_norm2"] = norm_params(mk, cfg.norm, d)
    return p


def _block_params(mk: ParamFactory, cfg: ModelConfig, pattern):
    return {str(i): _layer_params(mk, cfg, kind,
                                  _ffn_kind(cfg, kind, in_prefix=False))
            for i, kind in enumerate(pattern)}


def model_params(cfg: ModelConfig, mk: ParamFactory):
    prefix, pat, n_rep, suffix = stack_plan(cfg)
    p: Dict[str, Any] = {
        "embed": embed_params(mk, cfg.vocab_size, cfg.d_model,
                              cfg.tie_embeddings,
                              padded_vocab=cfg.padded_vocab()),
        "final_norm": norm_params(mk, cfg.norm, cfg.d_model),
    }
    if cfg.contribution_gate:
        # generalized Pix-Con: learned per-token contribution weighting
        # applied to the embedded stream (DESIGN.md §5)
        p["gate"] = gate_params(mk, cfg.d_model)
    if cfg.frontend == "audio_stub":
        p["frontend"] = {
            "proj": mk((cfg.frontend_dim, cfg.d_model), (None, "embed")),
            "proj_b": mk((cfg.d_model,), ("embed",), init="zeros"),
        }
    elif cfg.frontend == "vision_stub":
        p["frontend"] = {
            "w1": mk((cfg.frontend_dim, cfg.d_model), (None, "embed")),
            "b1": mk((cfg.d_model,), ("embed",), init="zeros"),
            "w2": mk((cfg.d_model, cfg.d_model), ("embed", None)),
            "b2": mk((cfg.d_model,), ("embed",), init="zeros"),
        }
    if prefix:
        p["prefix"] = tuple(
            _layer_params(mk, cfg, kind, _ffn_kind(cfg, kind, in_prefix=True))
            for kind in prefix)
    if n_rep:
        if mk.mode == "spec":
            block = _block_params(mk, cfg, pat)
            p["blocks"] = jax.tree.map(
                lambda ax: (None,) + ax, block,
                is_leaf=lambda x: isinstance(x, tuple) and all(
                    isinstance(e, (str, type(None))) for e in x))
        else:
            reps = [_block_params(mk, cfg, pat) for _ in range(n_rep)]
            p["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *reps)
    if suffix:
        p["suffix"] = tuple(
            _layer_params(mk, cfg, kind, _ffn_kind(cfg, kind, in_prefix=False))
            for kind in suffix)
    return p


def init(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32):
    return model_params(cfg, ParamFactory(key, mode="init", dtype=dtype))


def param_specs(cfg: ModelConfig):
    return model_params(cfg, ParamFactory(mode="spec"))


# ---------------------------------------------------------------------------
# Input embedding (with frontend stubs)
# ---------------------------------------------------------------------------
def embed_inputs(params, cfg: ModelConfig, inputs: Dict[str, jax.Array],
                 dtype=jnp.bfloat16) -> jax.Array:
    if cfg.frontend == "audio_stub":
        frames = inputs["frames"].astype(dtype)                  # (B,S,Fd)
        x = jnp.einsum("bsf,fd->bsd", frames,
                       params["frontend"]["proj"].astype(dtype))
        x = x + params["frontend"]["proj_b"].astype(dtype)
        # HuBERT conv-pos-emb stand-in: fixed sinusoidal table
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(dtype)[None]
        return constrain(x, ("batch", "seq", "embed"))
    if cfg.frontend == "vision_stub" and "patches" not in inputs:
        # decode steps carry tokens only (image prefix lives in the cache)
        return embed(params["embed"], inputs["tokens"],
                     scale=cfg.embed_scale, d_model=cfg.d_model, dtype=dtype)
    if cfg.frontend == "vision_stub":
        f = params["frontend"]
        patches = inputs["patches"].astype(dtype)                # (B,P,Fd)
        h = jax.nn.gelu(jnp.einsum("bpf,fd->bpd", patches,
                                   f["w1"].astype(dtype)) + f["b1"].astype(dtype))
        img = jnp.einsum("bpd,de->bpe", h, f["w2"].astype(dtype)) + f["b2"].astype(dtype)
        txt = embed(params["embed"], inputs["tokens"],
                    scale=cfg.embed_scale, d_model=cfg.d_model, dtype=dtype)
        return constrain(jnp.concatenate([img, txt], axis=1),
                         ("batch", "seq", "embed"))
    return embed(params["embed"], inputs["tokens"],
                 scale=cfg.embed_scale, d_model=cfg.d_model, dtype=dtype)


# ---------------------------------------------------------------------------
# Layer application (full sequence)
# ---------------------------------------------------------------------------
def _apply_ffn(lp, cfg: ModelConfig, x: jax.Array, ffn: str):
    if ffn == "none":
        return x, jnp.zeros((), jnp.float32)
    h = apply_norm(lp["norm2"], cfg.norm, x)
    if ffn == "dense":
        out = mlp_mod.mlp_block(lp["ffn"], cfg, h)
        aux = jnp.zeros((), jnp.float32)
    else:
        out, aux = moe_mod.moe_block_auto(lp["ffn"], cfg, h)
    if cfg.post_norms:
        out = apply_norm(lp["post_norm2"], cfg.norm, out)
    return x + out, aux


def apply_layer(lp, cfg: ModelConfig, x: jax.Array, kind: LayerKind,
                ffn: str, *, collect_cache: bool = False,
                max_len: int = 0):
    """Full-sequence layer.  Returns (x, aux, cache_entry_or_None)."""
    h = apply_norm(lp["norm1"], cfg.norm, x)
    cache_entry = None
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        if collect_cache:
            out, (k, v) = attn_mod.attention_block(
                lp["attn"], cfg, h, kind=kind, return_kv=True)
            L = attn_mod.cache_length(cfg, kind, max_len)
            empty = attn_mod.init_kv_cache(
                x.shape[0], L, cfg.num_kv_heads, cfg.resolved_head_dim(),
                dtype=x.dtype)
            cache_entry = attn_mod.fill_cache_from_prefill(empty, k, v)
        else:
            out = attn_mod.attention_block(lp["attn"], cfg, h, kind=kind)
    elif kind == RECURRENT:
        if collect_cache:
            out, cache_entry = rglru_mod.rglru_block(
                lp["rec"], cfg, h, return_state=True)
        else:
            out = rglru_mod.rglru_block(lp["rec"], cfg, h)
    elif kind == SSM:
        if collect_cache:
            out, cache_entry = ssm_mod.ssm_block(
                lp["ssm"], cfg, h, return_state=True)
        else:
            out = ssm_mod.ssm_block(lp["ssm"], cfg, h)
    else:
        raise ValueError(kind)
    if cfg.post_norms:
        out = apply_norm(lp["post_norm1"], cfg.norm, out)
    x = x + out
    x, aux = _apply_ffn(lp, cfg, x, ffn)
    return x, aux, cache_entry


def _apply_layer_step(lp, cfg: ModelConfig, x: jax.Array, kind: LayerKind,
                      ffn: str, mixer_fn):
    """Shared incremental-layer scaffold (norm -> mixer -> post-norm ->
    residual -> FFN) for the one-token and chunked paths; ``mixer_fn(lp,
    kind, h) -> (out, new_cache_entry)`` supplies the cached
    attention/recurrent step."""
    h = apply_norm(lp["norm1"], cfg.norm, x)
    out, new_entry = mixer_fn(lp, kind, h)
    if cfg.post_norms:
        out = apply_norm(lp["post_norm1"], cfg.norm, out)
    x = x + out
    x, _ = _apply_ffn(lp, cfg, x, ffn)
    return x, new_entry


def apply_layer_decode(lp, cfg: ModelConfig, x: jax.Array, kind: LayerKind,
                       ffn: str, cache_entry, position: jax.Array):
    """One-token layer step.  Returns (x, new_cache_entry)."""
    def mixer(lp_, kind_, h):
        if kind_ in (ATTN_GLOBAL, ATTN_LOCAL):
            window = cfg.window if kind_ == ATTN_LOCAL else None
            return attn_mod.decode_attention(
                lp_["attn"], cfg, h, cache_entry, position, window=window)
        if kind_ == RECURRENT:
            return rglru_mod.rglru_decode_step(lp_["rec"], cfg, h,
                                               cache_entry)
        if kind_ == SSM:
            return ssm_mod.ssm_decode_step(lp_["ssm"], cfg, h, cache_entry)
        raise ValueError(kind_)
    return _apply_layer_step(lp, cfg, x, kind, ffn, mixer)


# ---------------------------------------------------------------------------
# Full stack
# ---------------------------------------------------------------------------
def run_stack(params, cfg: ModelConfig, x: jax.Array, *,
              collect_cache: bool = False, max_len: int = 0,
              remat: bool = False):
    """x (B,S,d) -> (x, aux, caches) through prefix + scanned blocks + suffix."""
    if cfg.contribution_gate:
        x = contribution_gate(params["gate"], x)
    prefix, pat, n_rep, suffix = stack_plan(cfg)
    aux = jnp.zeros((), jnp.float32)
    caches: Dict[str, Any] = {}

    if prefix:
        entries = []
        for lp, kind in zip(params["prefix"], prefix):
            x, a, c = apply_layer(lp, cfg, x, kind,
                                  _ffn_kind(cfg, kind, in_prefix=True),
                                  collect_cache=collect_cache, max_len=max_len)
            aux = aux + a
            entries.append(c)
        if collect_cache:
            caches["prefix"] = tuple(entries)

    if n_rep:
        def body(carry, block_p):
            xx, au = carry
            entries = []
            for i, kind in enumerate(pat):
                xx, a, c = apply_layer(
                    block_p[str(i)], cfg, xx, kind,
                    _ffn_kind(cfg, kind, in_prefix=False),
                    collect_cache=collect_cache, max_len=max_len)
                au = au + a
                entries.append(c)
            ys = {str(i): e for i, e in enumerate(entries)} \
                if collect_cache else None
            return (xx, au), ys
        if remat:
            body = jax.checkpoint(body)
        (x, aux), block_caches = jax.lax.scan(body, (x, aux), params["blocks"],
                                              unroll=_scan_unroll())
        if collect_cache:
            caches["blocks"] = block_caches

    if suffix:
        entries = []
        for lp, kind in zip(params["suffix"], suffix):
            x, a, c = apply_layer(lp, cfg, x, kind,
                                  _ffn_kind(cfg, kind, in_prefix=False),
                                  collect_cache=collect_cache, max_len=max_len)
            aux = aux + a
            entries.append(c)
        if collect_cache:
            caches["suffix"] = tuple(entries)

    x = apply_norm(params["final_norm"], cfg.norm, x)
    return x, aux, (caches if collect_cache else None)


def forward(params, cfg: ModelConfig, inputs: Dict[str, jax.Array], *,
            dtype=jnp.bfloat16, remat: bool = False):
    """Returns (final hidden states (B,S,d), aux)."""
    x = embed_inputs(params, cfg, inputs, dtype)
    x, aux, _ = run_stack(params, cfg, x, remat=remat)
    return x, aux


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------
def lm_loss(params, cfg: ModelConfig, batch: Dict[str, jax.Array], *,
            dtype=jnp.bfloat16, remat: bool = False,
            loss_chunk: int = 512):
    """Token cross-entropy; logits computed in seq chunks (vocab sharded)."""
    x, aux = forward(params, cfg, batch, dtype=dtype, remat=remat)
    targets = batch["targets"]
    if cfg.frontend == "vision_stub":
        # image prefix carries no LM targets
        x = x[:, cfg.num_patches:]
    B, S, _ = x.shape
    mask = batch.get("loss_mask")

    chunk = min(loss_chunk, S)
    nch = (S + chunk - 1) // chunk
    pad = nch * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        m = jnp.pad(mask if mask is not None
                    else jnp.ones((B, S), jnp.float32), ((0, 0), (0, pad)))
    else:
        m = mask if mask is not None else jnp.ones((B, S), jnp.float32)

    xs = x.reshape(B, nch, chunk, -1).swapaxes(0, 1)
    ts = targets.reshape(B, nch, chunk).swapaxes(0, 1)
    ms = m.reshape(B, nch, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_nll(xc, tc, mc):
        # recomputed in backward: the (B,chunk,V) fp32 logits never live
        # across the whole loss scan
        logits = unembed(params["embed"], xc, tie=cfg.tie_embeddings,
                         cap=cfg.logit_softcap,
                         real_vocab=cfg.vocab_size)              # (B,chunk,V)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        return jnp.sum(nll), jnp.sum(mc)

    def body(carry, inp):
        tot, cnt = carry
        xc, tc, mc = inp
        nll, m_sum = chunk_nll(xc, tc, mc)
        return (tot + nll, cnt + m_sum), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xs, ts, ms), unroll=_scan_unroll())
    loss = tot / jnp.maximum(cnt, 1.0)
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_coef * aux
    return loss, {"ce": tot / jnp.maximum(cnt, 1.0), "aux": aux}


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------
def prefill(params, cfg: ModelConfig, inputs: Dict[str, jax.Array],
            max_len: int, *, dtype=jnp.bfloat16):
    """Full-context forward; returns (last-token logits (B,V), caches)."""
    x = embed_inputs(params, cfg, inputs, dtype)
    x, _, caches = run_stack(params, cfg, x, collect_cache=True,
                             max_len=max_len)
    last = x[:, -1:]
    logits = unembed(params["embed"], last, tie=cfg.tie_embeddings,
                     cap=cfg.logit_softcap, real_vocab=cfg.vocab_size)[:, 0]
    return logits, caches


def _decode_walk(params, cfg: ModelConfig, x: jax.Array, caches, layer_fn):
    """Shared prefix / scanned-blocks / suffix traversal for the one-token
    and chunked incremental paths.  ``layer_fn(lp, kind, ffn, cache_entry,
    x) -> (x, new_entry)`` supplies the per-layer step (contiguous decode,
    paged decode, or paged chunk prefill)."""
    prefix, pat, n_rep, suffix = stack_plan(cfg)
    new_caches: Dict[str, Any] = {}

    if prefix:
        entries = []
        for lp, kind, ce in zip(params["prefix"], prefix, caches["prefix"]):
            x, ne = layer_fn(lp, kind, _ffn_kind(cfg, kind, in_prefix=True),
                             ce, x)
            entries.append(ne)
        new_caches["prefix"] = tuple(entries)

    if n_rep:
        def body(xx, inp):
            block_p, block_c = inp
            entries = []
            for i, kind in enumerate(pat):
                xx, ne = layer_fn(block_p[str(i)], kind,
                                  _ffn_kind(cfg, kind, in_prefix=False),
                                  block_c[str(i)], xx)
                entries.append(ne)
            return xx, {str(i): e for i, e in enumerate(entries)}
        x, block_caches = jax.lax.scan(
            body, x, (params["blocks"], caches["blocks"]),
            unroll=_scan_unroll())
        new_caches["blocks"] = block_caches

    if suffix:
        entries = []
        for lp, kind, ce in zip(params["suffix"], suffix, caches["suffix"]):
            x, ne = layer_fn(lp, kind, _ffn_kind(cfg, kind, in_prefix=False),
                             ce, x)
            entries.append(ne)
        new_caches["suffix"] = tuple(entries)
    return x, new_caches


def _finish_logits(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = apply_norm(params["final_norm"], cfg.norm, x)
    return unembed(params["embed"], x, tie=cfg.tie_embeddings,
                   cap=cfg.logit_softcap, real_vocab=cfg.vocab_size)


def decode_step(params, cfg: ModelConfig, inputs: Dict[str, jax.Array],
                caches, position: jax.Array, *, dtype=jnp.bfloat16):
    """One decode step: token (B,1) + caches -> (logits (B,V), new caches).

    ``position`` is either a scalar (every row at the same index — the
    single-request path) or a (B,) int32 vector of per-row indices: the
    serve engine's continuous-batching path, where each cache row is a
    request slot advancing its own position counter (requests with ragged
    prompt lengths therefore coexist in one decode batch)."""
    x = embed_inputs(params, cfg, inputs, dtype)
    if cfg.contribution_gate:
        x = contribution_gate(params["gate"], x)

    def layer_fn(lp, kind, ffn, ce, xx):
        return apply_layer_decode(lp, cfg, xx, kind, ffn, ce, position)

    x, new_caches = _decode_walk(params, cfg, x, caches, layer_fn)
    logits = _finish_logits(params, cfg, x)[:, 0]
    return logits, new_caches


# ---------------------------------------------------------------------------
# Paged decode / chunked prefill
#
# Attention layers read and write a shared page pool through per-slot page
# tables (models/attention.py); recurrent and SSM layers keep their O(1)
# slot-major state — paging only applies where memory grows with context.
# ---------------------------------------------------------------------------
def _mask_state_update(new_entry, old_entry, active: jax.Array):
    """Keep ``old_entry`` rows where ``active`` (S,) is False, so the fused
    all-slot decode step cannot advance the recurrent state of a free slot
    or of a slot that is mid-chunked-prefill."""
    def _sel(n, o):
        m = active.reshape((-1,) + (1,) * (n.ndim - 1))
        return jnp.where(m, n.astype(o.dtype), o)
    return jax.tree.map(_sel, new_entry, old_entry)


def decode_step_paged(params, cfg: ModelConfig, inputs: Dict[str, jax.Array],
                      caches, position: jax.Array, page_table: jax.Array,
                      active: jax.Array, *, dtype=jnp.bfloat16):
    """Fused all-slot decode against the paged cache.  ``page_table``
    (S, pages_per_slot) int32 physical page ids per slot (-1 unassigned);
    ``active`` (S,) bool gates every state write — inactive slots neither
    write KV pages nor advance recurrent state."""
    x = embed_inputs(params, cfg, inputs, dtype)
    if cfg.contribution_gate:
        x = contribution_gate(params["gate"], x)

    def layer_fn(lp, kind, ffn, ce, xx):
        def mixer(lp_, kind_, h):
            if kind_ in (ATTN_GLOBAL, ATTN_LOCAL):
                window = cfg.window if kind_ == ATTN_LOCAL else None
                return attn_mod.paged_decode_attention(
                    lp_["attn"], cfg, h, ce, page_table, position,
                    window=window, active=active)
            if kind_ == RECURRENT:
                out, ne = rglru_mod.rglru_decode_step(lp_["rec"], cfg, h, ce)
            elif kind_ == SSM:
                out, ne = ssm_mod.ssm_decode_step(lp_["ssm"], cfg, h, ce)
            else:
                raise ValueError(kind_)
            return out, _mask_state_update(ne, ce, active)
        return _apply_layer_step(lp, cfg, xx, kind, ffn, mixer)

    x, new_caches = _decode_walk(params, cfg, x, caches, layer_fn)
    logits = _finish_logits(params, cfg, x)[:, 0]
    return logits, new_caches


def _verify_recurrent(step_fn, lp, cfg: ModelConfig, x: jax.Array, entry):
    """Run a one-token recurrent/SSM step over the T proposed tokens for
    ALL slots at once, collecting the state after EVERY step: the verify
    boundary rolls a slot back to the state at its last accepted token by
    selecting from the stacked snapshots (``serve.state.select_verified``),
    so a rejected draft can never leave a residue in the recurrence.
    Returns (out (S,T,d), stacked states with a leading step axis)."""
    def body(carry, xt):                    # xt (S, d) — one proposed token
        out_t, ns = step_fn(lp, cfg, xt[:, None, :], carry)
        return ns, (out_t[:, 0], ns)

    _, (outs, stacked) = jax.lax.scan(body, entry, x.swapaxes(0, 1))
    return outs.swapaxes(0, 1), stacked


def verify_step_paged(params, cfg: ModelConfig, inputs: Dict[str, jax.Array],
                      caches, position: jax.Array, page_table: jax.Array,
                      active: jax.Array, *, dtype=jnp.bfloat16):
    """Draft-verification forward: one fused chunk-style step over ALL
    request slots.  ``inputs["tokens"]`` (S, T) holds, per slot, the last
    accepted token followed by T-1 drafted tokens, starting at the slot's
    ``position``; the step returns logits at EVERY proposed position (the
    greedy acceptance rule runs on them outside this function).

    Cache semantics differ from ``decode_step_paged`` in exactly the two
    places speculation needs:

      * attention layers write all T tokens' K/V into the slot's pages
        (``paged_multitok_attention``) — rejected positions need no undo,
        because the positional mask hides any entry with pos greater than
        a later query's position until the real sequence overwrites it;
      * recurrent/SSM layers return their state stacked per step (leading
        T axis) instead of the final state, so the caller can select the
        snapshot at each slot's last accepted token.

    ``active`` (S,) bool gates the page writes; inactive slots' recurrent
    rows are restored at selection time.  Returns (logits (S, T, V),
    caches-with-stacked-recurrent-leaves)."""
    x = embed_inputs(params, cfg, inputs, dtype)
    if cfg.contribution_gate:
        x = contribution_gate(params["gate"], x)

    def layer_fn(lp, kind, ffn, ce, xx):
        def mixer(lp_, kind_, h):
            if kind_ in (ATTN_GLOBAL, ATTN_LOCAL):
                window = cfg.window if kind_ == ATTN_LOCAL else None
                return attn_mod.paged_multitok_attention(
                    lp_["attn"], cfg, h, ce, page_table, position,
                    window=window, active=active)
            if kind_ == RECURRENT:
                return _verify_recurrent(rglru_mod.rglru_decode_step,
                                         lp_["rec"], cfg, h, ce)
            if kind_ == SSM:
                return _verify_recurrent(ssm_mod.ssm_decode_step,
                                         lp_["ssm"], cfg, h, ce)
            raise ValueError(kind_)
        return _apply_layer_step(lp, cfg, xx, kind, ffn, mixer)

    x, new_caches = _decode_walk(params, cfg, x, caches, layer_fn)
    logits = _finish_logits(params, cfg, x)                     # (S, T, V)
    return logits, new_caches


def _chunk_recurrent(step_fn, lp, cfg: ModelConfig, x: jax.Array, entry,
                     slot: jax.Array, pos_start: jax.Array):
    """Run a one-token recurrent/SSM step over a chunk for ONE slot: slice
    the slot's state row, scan the step over the chunk tokens (recurrence
    is inherently sequential), write the final state back in place.  The
    first chunk of a prompt (pos_start == 0) starts the recurrence from
    zeros — the slot row may hold stale state from an evicted request."""
    st = jax.tree.map(
        lambda s: jax.lax.dynamic_slice_in_dim(s, slot, 1, axis=0), entry)
    st = jax.tree.map(
        lambda s: jnp.where(pos_start == 0, jnp.zeros_like(s), s), st)

    def body(carry, xt):                    # xt (1, d) — one chunk token
        out_t, ns = step_fn(lp, cfg, xt[:, None, :], carry)
        return ns, out_t[:, 0]

    st_new, outs = jax.lax.scan(body, st, x.swapaxes(0, 1))
    new_entry = jax.tree.map(
        lambda full, one: jax.lax.dynamic_update_slice_in_dim(
            full, one.astype(full.dtype), slot, axis=0), entry, st_new)
    return outs.swapaxes(0, 1), new_entry


def prefill_chunk(params, cfg: ModelConfig, inputs: Dict[str, jax.Array],
                  caches, page_row: jax.Array, slot: jax.Array,
                  pos_start: jax.Array, *, dtype=jnp.bfloat16):
    """One chunked-prefill step for ONE request slot.  ``inputs["tokens"]``
    (1, C) is the chunk starting at absolute position ``pos_start``; KV is
    written into the slot's pages and recurrent state advances in the
    slot's row, so admission interleaves with fused decode steps without
    touching any other slot.  Returns (last-token logits (1, V), caches).
    """
    x = embed_inputs(params, cfg, inputs, dtype)
    if cfg.contribution_gate:
        x = contribution_gate(params["gate"], x)

    def layer_fn(lp, kind, ffn, ce, xx):
        def mixer(lp_, kind_, h):
            if kind_ in (ATTN_GLOBAL, ATTN_LOCAL):
                window = cfg.window if kind_ == ATTN_LOCAL else None
                return attn_mod.paged_prefill_attention(
                    lp_["attn"], cfg, h, ce, page_row, pos_start,
                    window=window)
            if kind_ == RECURRENT:
                return _chunk_recurrent(rglru_mod.rglru_decode_step,
                                        lp_["rec"], cfg, h, ce, slot,
                                        pos_start)
            if kind_ == SSM:
                return _chunk_recurrent(ssm_mod.ssm_decode_step,
                                        lp_["ssm"], cfg, h, ce, slot,
                                        pos_start)
            raise ValueError(kind_)
        return _apply_layer_step(lp, cfg, xx, kind, ffn, mixer)

    x, new_caches = _decode_walk(params, cfg, x, caches, layer_fn)
    logits = _finish_logits(params, cfg, x)[:, -1]
    return logits, new_caches


def scatter_prefill_paged(cfg: ModelConfig, paged_caches, prefill_caches,
                          page_row: jax.Array, slot: jax.Array):
    """Write a whole-prompt prefill cache (from ``prefill``, batch 1) into
    the paged state: KV rings map into the slot's pages, recurrent/SSM
    state scatters into the slot's row.  KVCache and PagedKVCache trees
    differ structurally, so this walks the stack plan entry by entry."""
    prefix, pat, n_rep, suffix = stack_plan(cfg)

    def one(kind, pooled, fresh, stacked: bool):
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            if stacked:                     # leading layer-repetition axis
                return jax.vmap(
                    lambda p, f: attn_mod.paged_fill_from_prefill(
                        p, f, page_row))(pooled, fresh)
            return attn_mod.paged_fill_from_prefill(pooled, fresh, page_row)
        ax = 1 if stacked else 0
        return jax.tree.map(
            lambda full, onearr: jax.lax.dynamic_update_slice_in_dim(
                full, onearr.astype(full.dtype), slot, axis=ax),
            pooled, fresh)

    out: Dict[str, Any] = {}
    if prefix:
        out["prefix"] = tuple(
            one(kind, paged_caches["prefix"][i], prefill_caches["prefix"][i],
                False) for i, kind in enumerate(prefix))
    if n_rep:
        out["blocks"] = {
            str(i): one(kind, paged_caches["blocks"][str(i)],
                        prefill_caches["blocks"][str(i)], True)
            for i, kind in enumerate(pat)}
    if suffix:
        out["suffix"] = tuple(
            one(kind, paged_caches["suffix"][i], prefill_caches["suffix"][i],
                False) for i, kind in enumerate(suffix))
    return out


# ---------------------------------------------------------------------------
# Cache construction (and ShapeDtypeStruct specs for the dry-run)
# ---------------------------------------------------------------------------
def _layer_cache(cfg: ModelConfig, kind: LayerKind, batch: int, max_len: int,
                 dtype):
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        L = attn_mod.cache_length(cfg, kind, max_len)
        return attn_mod.init_kv_cache(batch, L, cfg.num_kv_heads,
                                      cfg.resolved_head_dim(), dtype)
    if kind == RECURRENT:
        return rglru_mod.init_rglru_state(cfg, batch)
    if kind == SSM:
        return ssm_mod.init_ssm_state(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    prefix, pat, n_rep, suffix = stack_plan(cfg)
    caches: Dict[str, Any] = {}
    if prefix:
        caches["prefix"] = tuple(
            _layer_cache(cfg, k, batch, max_len, dtype) for k in prefix)
    if n_rep:
        block = {str(i): _layer_cache(cfg, k, batch, max_len, dtype)
                 for i, k in enumerate(pat)}
        caches["blocks"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_rep,) + a.shape), block)
    if suffix:
        caches["suffix"] = tuple(
            _layer_cache(cfg, k, batch, max_len, dtype) for k in suffix)
    return caches


def _layer_cache_axes(cfg: ModelConfig, kind: LayerKind):
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        return attn_mod.kv_cache_axes()
    if kind == RECURRENT:
        return rglru_mod.rglru_state_axes()
    if kind == SSM:
        return ssm_mod.ssm_state_axes()
    raise ValueError(kind)


def cache_axes(cfg: ModelConfig):
    """Logical-axes pytree matching init_cache structure."""
    prefix, pat, n_rep, suffix = stack_plan(cfg)
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    caches: Dict[str, Any] = {}
    if prefix:
        caches["prefix"] = tuple(_layer_cache_axes(cfg, k) for k in prefix)
    if n_rep:
        block = {str(i): _layer_cache_axes(cfg, k) for i, k in enumerate(pat)}
        caches["blocks"] = jax.tree.map(lambda ax: (None,) + ax, block,
                                        is_leaf=is_axes)
    if suffix:
        caches["suffix"] = tuple(_layer_cache_axes(cfg, k) for k in suffix)
    return caches


def _layer_paged_cache(cfg: ModelConfig, kind: LayerKind, slots: int,
                       num_pages: int, page_size: int, dtype):
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        return attn_mod.init_paged_kv_cache(
            num_pages, page_size, cfg.num_kv_heads, cfg.resolved_head_dim(),
            dtype)
    return _layer_cache(cfg, kind, slots, 0, dtype)


def init_paged_cache(cfg: ModelConfig, slots: int, num_pages: int,
                     page_size: int, dtype=jnp.bfloat16):
    """Paged twin of ``init_cache``: attention layers hold a page pool of
    ``num_pages`` pages (slot count decoupled from cache length — memory
    scales with live tokens); recurrent/SSM layers keep O(1) slot-major
    state."""
    prefix, pat, n_rep, suffix = stack_plan(cfg)
    caches: Dict[str, Any] = {}
    if prefix:
        caches["prefix"] = tuple(
            _layer_paged_cache(cfg, k, slots, num_pages, page_size, dtype)
            for k in prefix)
    if n_rep:
        block = {str(i): _layer_paged_cache(cfg, k, slots, num_pages,
                                            page_size, dtype)
                 for i, k in enumerate(pat)}
        caches["blocks"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_rep,) + a.shape), block)
    if suffix:
        caches["suffix"] = tuple(
            _layer_paged_cache(cfg, k, slots, num_pages, page_size, dtype)
            for k in suffix)
    return caches


def _layer_paged_cache_axes(cfg: ModelConfig, kind: LayerKind):
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        return attn_mod.paged_kv_cache_axes()
    return _layer_cache_axes(cfg, kind)


def paged_cache_axes(cfg: ModelConfig):
    """Logical-axes pytree matching init_paged_cache structure."""
    prefix, pat, n_rep, suffix = stack_plan(cfg)
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    caches: Dict[str, Any] = {}
    if prefix:
        caches["prefix"] = tuple(
            _layer_paged_cache_axes(cfg, k) for k in prefix)
    if n_rep:
        block = {str(i): _layer_paged_cache_axes(cfg, k)
                 for i, k in enumerate(pat)}
        caches["blocks"] = jax.tree.map(lambda ax: (None,) + ax, block,
                                        is_leaf=is_axes)
    if suffix:
        caches["suffix"] = tuple(
            _layer_paged_cache_axes(cfg, k) for k in suffix)
    return caches
