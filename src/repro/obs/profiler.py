"""Optional ``jax.profiler`` integration.

Two pieces, both inert unless a profile window is open:

  * :func:`start` / :func:`stop` / :func:`profile` — wrap
    ``jax.profiler.start_trace`` so ``--profile-dir`` on either launcher
    captures a device trace (open the run directory in TensorBoard's
    profile plugin or ui.perfetto.dev);
  * :func:`annotate` — a ``jax.profiler.TraceAnnotation`` scope the
    engines place around prefill/decode/verify/restore DISPATCH, so the
    host-side phase names line up with device timelines on real
    hardware.  When no window is active (the common case, and always in
    unit tests) it returns a null context and costs one attribute read —
    annotation can never perturb numerics or show up in the digest
    parity tests.
"""
from __future__ import annotations

from contextlib import contextmanager, nullcontext

_active = False


def active() -> bool:
    return _active


def start(profile_dir: str) -> None:
    """Open a ``jax.profiler`` trace window writing to ``profile_dir``."""
    global _active
    import jax

    jax.profiler.start_trace(profile_dir)
    _active = True


def stop() -> None:
    global _active
    if not _active:
        return
    import jax

    jax.profiler.stop_trace()
    _active = False


@contextmanager
def profile(profile_dir=None):
    """Profile window for the duration of the block when ``profile_dir``
    is set; no-op otherwise — lets launchers write
    ``with profiler.profile(args.profile_dir): ...`` unconditionally."""
    if not profile_dir:
        yield
        return
    start(profile_dir)
    try:
        yield
    finally:
        stop()


def annotate(name: str):
    """``jax.profiler.TraceAnnotation(name)`` while a profile window is
    open, else a null context."""
    if not _active:
        return nullcontext()
    import jax

    return jax.profiler.TraceAnnotation(name)
