"""Metric registry: counters, gauges, streaming histograms, labeled series.

One :class:`MetricRegistry` instance is the single store for every
host-side measurement a subsystem makes — the serving scheduler's
per-run / lifetime stats, per-request TTFT, decode-gap distributions,
training step timings, loader-wait gauges.  Code that used to keep flat
``stats`` dicts keeps its dict API through :class:`StatGroup` /
:class:`Series` views; the registry gains what the flat dicts never had:

  * HISTOGRAMS with quantiles — exact on smoke-sized runs (every sample
    is kept up to ``exact_max``), deterministic decimation beyond it:
    when the sample buffer overflows it is sorted and every second
    sample dropped (first and last kept), doubling the per-sample
    weight, so ``quantile`` stays an empirical-CDF read with bounded
    rank error and zero randomness.  ``count``/``sum``/``min``/``max``
    stay exact at any size;
  * a uniform SNAPSHOT (``snapshot()``) and JSONL dump
    (``dump_jsonl``) — one line per metric, histograms summarized as
    count/sum/min/max/mean/p50/p90/p99 — the ``--metrics-out`` file the
    launchers write and ``scripts/ci_smoke.py obs`` validates;
  * NAMING: dotted lowercase paths, ``<subsystem>.<metric>[_<unit>]``
    (``serve.ttft_s``, ``serve.decode_gap_s``, ``sched.run.<counter>``,
    ``train.step_s``).  Units ride the suffix (``_s`` seconds,
    ``_tokens``, ``_pages``) so downstream tooling never guesses.

Everything here is pure host-side Python: recording a metric can never
perturb a jitted computation, which is what keeps the tracing/metrics
bit-parity tests (``tests/test_obs.py``) trivially true.
"""
from __future__ import annotations

import json
import time
from collections.abc import MutableMapping
from typing import Any, Dict, Iterable, Iterator, Mapping, Tuple

import numpy as np


class Counter:
    """Monotonic float counter."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += float(n)


class Gauge:
    """Last-write-wins float value."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = float("nan")

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Streaming histogram with exact-on-smoke quantiles.

    All samples are kept verbatim until ``exact_max``; past that the
    sorted buffer is decimated in place (every second sample dropped,
    endpoints kept) and the per-sample ``weight`` doubles — a
    deterministic quantile sketch whose rank error halves the resolution
    per decimation but never depends on arrival order randomness.
    ``quantile(q)`` is ``numpy.percentile`` over the buffer, so in the
    exact regime it matches ``numpy.percentile`` of the raw stream
    bit for bit (the hypothesis property test in ``tests/test_obs.py``
    pins this).  ``count``/``sum``/``min``/``max``/``last`` are exact at
    any size.
    """

    def __init__(self, exact_max: int = 4096) -> None:
        if exact_max < 2:
            raise ValueError(f"exact_max must be >= 2, got {exact_max}")
        self.exact_max = int(exact_max)
        self._samples: list = []
        self.reset()

    def reset(self) -> None:
        """Drop every recorded sample (the per-run reset, mirroring
        ``StatGroup.reset``)."""
        self._samples.clear()
        self.weight = 1                 # stream samples per kept sample
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.last = float("nan")

    @property
    def exact(self) -> bool:
        return self.weight == 1

    def record(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        self.last = v
        # past the exact regime only every ``weight``-th stream sample
        # is buffered, so every kept sample represents the same stream
        # mass and repeated decimation cannot skew toward recent values
        if self.count % self.weight == 0:
            self._samples.append(v)
        if len(self._samples) > self.exact_max:
            s = sorted(self._samples)
            # keep endpoints so min/max stay representable in the sketch
            self._samples = s[0::2] + ([s[-1]] if len(s) % 2 == 0 else [])
            self.weight *= 2

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def quantile(self, q: float) -> float:
        """The ``q``-th percentile (0..100, numpy.percentile semantics);
        exact while no decimation has happened, NaN when empty."""
        if not self._samples:
            return float("nan")
        return float(np.percentile(np.asarray(self._samples, np.float64), q))

    def quantiles(self, qs: Iterable[float] = (50, 90, 99)) -> Dict[str, float]:
        return {f"p{_fmt_q(q)}": self.quantile(q) for q in qs}

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "sum": self.sum,
                "min": self.min if self.count else float("nan"),
                "max": self.max if self.count else float("nan"),
                "mean": self.mean, **self.quantiles()}


def _fmt_q(q: float) -> str:
    return str(int(q)) if float(q) == int(q) else str(q).replace(".", "_")


def percentiles(values, qs: Iterable[float] = (50, 99)) -> Dict[str, float]:
    """``{"p50": ..., "p99": ...}`` over ``values`` — THE percentile
    helper every consumer shares (``launch/serve.py`` for the JSON
    summary, ``benchmarks/serve_bench.py`` for its latency rows), so the
    interpolation rule can never drift between them.  NaNs on empty."""
    vals = np.asarray(list(values), np.float64)
    if vals.size == 0:
        return {f"p{_fmt_q(q)}": float("nan") for q in qs}
    return {f"p{_fmt_q(q)}": float(np.percentile(vals, q)) for q in qs}


class Series(MutableMapping):
    """Labeled value family (``name{label} -> float``) with a plain dict
    API — e.g. ``serve.ttft_s`` keyed by request id.  The scheduler's
    legacy ``sched.ttft`` dict is exactly this view, so existing callers
    (``benchmarks/serve_bench.py``) keep indexing it unchanged while the
    registry snapshot/dump sees every point."""

    def __init__(self, name: str) -> None:
        self.name = name
        self._vals: Dict[Any, float] = {}

    def __getitem__(self, k: Any) -> float:
        return self._vals[k]

    def __setitem__(self, k: Any, v: float) -> None:
        self._vals[k] = float(v)

    def __delitem__(self, k: Any) -> None:
        del self._vals[k]

    def __iter__(self) -> Iterator:
        return iter(self._vals)

    def __len__(self) -> int:
        return len(self._vals)

    def __repr__(self) -> str:
        return f"Series({self.name!r}, {self._vals!r})"


class StatGroup(MutableMapping):
    """A fixed family of named scalars behind a dict API — the
    backward-compatible view that absorbs the scheduler's flat
    ``stats`` / ``lifetime_stats`` dicts.  Every existing access
    pattern keeps working (``g[k] += v``, ``g[k] = max(g[k], v)``,
    ``.items()``, ``dict(g)``); ``reset()`` restores the declared
    defaults (the per-run stats reset), and the registry's snapshot
    reports each key as ``<prefix>.<key>``."""

    def __init__(self, prefix: str, defaults: Mapping[str, float]) -> None:
        self.prefix = prefix
        self._defaults = dict(defaults)
        self._vals: Dict[str, float] = dict(defaults)

    def reset(self) -> None:
        self._vals = dict(self._defaults)

    def merge_defaults(self, defaults: Mapping[str, float]) -> None:
        for k, v in defaults.items():
            self._defaults.setdefault(k, v)
            self._vals.setdefault(k, v)

    def __getitem__(self, k: str) -> float:
        return self._vals[k]

    def __setitem__(self, k: str, v: float) -> None:
        self._vals[k] = v

    def __delitem__(self, k: str) -> None:
        del self._vals[k]

    def __iter__(self) -> Iterator[str]:
        return iter(self._vals)

    def __len__(self) -> int:
        return len(self._vals)

    def __repr__(self) -> str:
        return f"StatGroup({self.prefix!r}, {self._vals!r})"


class MetricRegistry:
    """Get-or-create store for every metric family; the single source a
    snapshot or ``--metrics-out`` dump reads."""

    def __init__(self) -> None:
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}
        self._series: Dict[str, Series] = {}
        self._groups: Dict[str, StatGroup] = {}
        self._t0 = time.perf_counter()

    # -- get-or-create accessors ------------------------------------------
    def counter(self, name: str) -> Counter:
        return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        return self._gauges.setdefault(name, Gauge())

    def histogram(self, name: str, exact_max: int = 4096) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram(exact_max=exact_max)
        return h

    def series(self, name: str) -> Series:
        s = self._series.get(name)
        if s is None:
            s = self._series[name] = Series(name)
        return s

    def group(self, prefix: str, defaults: Mapping[str, float], *,
              reset: bool = False) -> StatGroup:
        g = self._groups.get(prefix)
        if g is None:
            g = self._groups[prefix] = StatGroup(prefix, defaults)
        else:
            g.merge_defaults(defaults)
            if reset:
                g.reset()
        return g

    def elapsed(self) -> float:
        return time.perf_counter() - self._t0

    # -- export ------------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """Flat ``{dotted name: value}`` view: scalars directly,
        histograms as summary dicts, series as ``name{label}`` keys."""
        out: Dict[str, Any] = {}
        for name, c in self._counters.items():
            out[name] = c.value
        for name, g in self._gauges.items():
            out[name] = g.value
        for prefix, grp in self._groups.items():
            for k, v in grp.items():
                out[f"{prefix}.{k}"] = v
        for name, h in self._hists.items():
            out[name] = h.summary()
        for name, s in self._series.items():
            for label, v in s.items():
                out[f"{name}{{{label}}}"] = v
        return out

    def _lines(self) -> Iterator[Tuple[str, Dict[str, Any]]]:
        for name, c in self._counters.items():
            yield name, {"type": "counter", "value": c.value}
        for name, g in self._gauges.items():
            yield name, {"type": "gauge", "value": g.value}
        for prefix, grp in self._groups.items():
            for k, v in grp.items():
                yield f"{prefix}.{k}", {"type": "counter", "value": v}
        for name, h in self._hists.items():
            yield name, {"type": "histogram", **h.summary(),
                         "exact": h.exact}
        for name, s in self._series.items():
            for label, v in s.items():
                yield name, {"type": "series", "label": str(label),
                             "value": v}

    def dump_jsonl(self, path: str) -> None:
        """One JSON object per line: ``{"name", "type", ...}`` —
        counters/gauges carry ``value``, histograms their summary
        (count/sum/min/max/mean/p50/p90/p99), series one line per label.
        The schema ``benchmarks/README.md`` documents and the ``obs`` CI
        smoke validates."""
        with open(path, "w") as f:
            for name, doc in self._lines():
                f.write(json.dumps({"name": name, **_finite(doc)}) + "\n")


def _finite(doc: Dict[str, Any]) -> Dict[str, Any]:
    """NaN/inf -> None so the JSONL stays strict-JSON parseable."""
    out = {}
    for k, v in doc.items():
        if isinstance(v, float) and not np.isfinite(v):
            v = None
        out[k] = v
    return out
