"""Request-lifecycle tracer: spans with monotonic timestamps, exported as
Chrome trace-event JSON (open in Perfetto / ``chrome://tracing``).

Span taxonomy (the names the scheduler emits; see
``src/repro/serve/README.md`` for the full walk-through):

  * track ``sched`` — the scheduler's compute phases, strictly nested
    because the loop is single-threaded: ``run`` > ``iter`` > one of
    ``admit`` (containing ``prefix_match``, plus ``cow`` /
    ``restore_pages`` when the prefix cache maps pages),
    ``prefill_insert``, ``prefill_chunk``, ``decode_step`` (containing
    ``spec_propose`` / ``spec_verify`` on speculative rounds),
    ``swap_out``, ``swap_in``; ``spill`` spans fire inside whichever
    admission triggered the pool reclaim; ``defer`` is an instant;
  * track ``rid<N>`` — one request's lifecycle as back-to-back spans:
    ``queued`` (run start / arrival -> admission), ``prefill``
    (admission -> first emitted token), ``decode`` (first token ->
    finish), ``preempted`` (swap-out -> restore, splitting ``decode``),
    closed by a ``finish`` instant carrying the token count.

Timestamps come from one ``time.perf_counter`` epoch per tracer, in
microseconds — monotonic within a trace, and shared with the metric
values derived from it: the scheduler records TTFT and its lifecycle
span boundary from the SAME clock read, so span-derived request metrics
(:func:`derive_request_metrics`) agree with the legacy ``sched.ttft``
dict to float precision, not merely "within a millisecond".

A disabled tracer (``Tracer(enabled=False)``) drops everything at the
``begin``/``instant`` call site; tracing is pure host-side bookkeeping
either way, so emitted token streams are bit-identical with tracing on
or off (pinned by ``tests/test_obs.py``).
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional, Tuple


class Tracer:
    """Span collector with begin/end handles and a Chrome-trace export."""

    def __init__(self, enabled: bool = True,
                 max_events: int = 1_000_000) -> None:
        self.enabled = bool(enabled)
        self.max_events = int(max_events)
        self._t0 = time.perf_counter()
        self._events: List[Dict[str, Any]] = []
        self._open: Dict[int, Tuple[str, str, float, Dict[str, Any]]] = {}
        self._next = 0

    # -- clocks ------------------------------------------------------------
    @property
    def t0(self) -> float:
        """The ``time.perf_counter`` value at ts == 0."""
        return self._t0

    def now(self) -> float:
        """Current ``time.perf_counter`` — the clock every span uses, so
        callers deriving their own metrics stay on the span timebase."""
        return time.perf_counter()

    def _us(self, at: Optional[float]) -> float:
        return ((time.perf_counter() if at is None else at)
                - self._t0) * 1e6

    # -- recording ---------------------------------------------------------
    def begin(self, name: str, tid: str = "sched",
              at: Optional[float] = None, **args) -> Optional[int]:
        """Open a span; returns the handle ``end`` closes (None when
        disabled).  ``at`` pins the start to an explicit perf_counter
        read (e.g. the run start for ``queued`` spans)."""
        if not self.enabled:
            return None
        h = self._next
        self._next += 1
        self._open[h] = (name, tid, self._us(at), args)
        return h

    def end(self, handle: Optional[int], at: Optional[float] = None,
            **extra) -> None:
        if handle is None or not self.enabled:
            return
        ent = self._open.pop(handle, None)
        if ent is None:
            return
        name, tid, ts, args = ent
        if extra:
            args = {**args, **extra}
        self._push({"name": name, "ph": "X", "ts": ts,
                    "dur": max(self._us(at) - ts, 0.0), "tid": tid,
                    "args": args})

    @contextmanager
    def span(self, name: str, tid: str = "sched", **args):
        h = self.begin(name, tid, **args)
        try:
            yield
        finally:
            self.end(h)

    def instant(self, name: str, tid: str = "sched",
                at: Optional[float] = None, **args) -> None:
        if not self.enabled:
            return
        self._push({"name": name, "ph": "i", "ts": self._us(at),
                    "tid": tid, "args": args})

    def _push(self, ev: Dict[str, Any]) -> None:
        if len(self._events) < self.max_events:
            self._events.append(ev)

    # -- export ------------------------------------------------------------
    def events(self) -> List[Dict[str, Any]]:
        """The completed events, string ``tid``s, ts/dur in µs."""
        return list(self._events)

    def to_chrome(self) -> Dict[str, Any]:
        """Chrome trace-event JSON: one process, one numeric thread per
        track, ``thread_name`` metadata naming each, ``X``/``i`` events
        sorted by ts — drag the file into https://ui.perfetto.dev."""
        tids: Dict[str, int] = {}
        out: List[Dict[str, Any]] = []
        for ev in sorted(self._events, key=lambda e: e["ts"]):
            t = tids.setdefault(ev["tid"], len(tids))
            out.append({**ev, "pid": 0, "tid": t})
        meta = [{"name": "thread_name", "ph": "M", "pid": 0, "tid": t,
                 "args": {"name": name}} for name, t in tids.items()]
        # keep the scheduler track above the per-request tracks in the UI
        order = [{"name": "thread_sort_index", "ph": "M", "pid": 0,
                  "tid": t, "args": {"sort_index": t}}
                 for t in tids.values()]
        return {"traceEvents": meta + order + out,
                "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
            f.write("\n")


def derive_request_metrics(events) -> Dict[int, Dict[str, float]]:
    """Per-request latency metrics FROM the lifecycle spans (not from any
    side-channel timer): ``{rid: {queue_s, ttft_s, decode_s, tpot_s,
    tokens}}``.

      * ``queue_s``  — the ``queued`` span's duration;
      * ``ttft_s``   — arrival (``queued`` start) to first emitted token
                       (``prefill`` end); equals the scheduler's legacy
                       ``ttft`` dict because both read one clock;
      * ``decode_s`` — summed ``decode`` span durations (preemption
                       splits them);
      * ``tpot_s``   — decode seconds per token after the first;
      * ``tokens``   — from the ``finish`` instant.
    """
    per: Dict[int, Dict[str, float]] = {}
    for ev in events:
        args = ev.get("args", {})
        rid = args.get("rid")
        if rid is None or not str(ev.get("tid", "")).startswith("rid"):
            continue
        d = per.setdefault(int(rid), {"queue_s": 0.0, "ttft_s": 0.0,
                                      "decode_s": 0.0, "tpot_s": 0.0,
                                      "tokens": 0, "_arrive": None,
                                      "_first": None})
        if ev["ph"] == "i" and ev["name"] == "finish":
            d["tokens"] = int(args.get("tokens", 0))
            continue
        if ev["ph"] != "X":
            continue
        if ev["name"] == "queued":
            d["queue_s"] += ev["dur"] / 1e6
            d["_arrive"] = ev["ts"] if d["_arrive"] is None \
                else min(d["_arrive"], ev["ts"])
        elif ev["name"] == "prefill":
            end = ev["ts"] + ev["dur"]
            d["_first"] = end if d["_first"] is None \
                else max(d["_first"], end)
        elif ev["name"] == "decode":
            d["decode_s"] += ev["dur"] / 1e6
    for d in per.values():
        if d["_arrive"] is not None and d["_first"] is not None:
            d["ttft_s"] = (d["_first"] - d["_arrive"]) / 1e6
        if d["tokens"] > 1:
            d["tpot_s"] = d["decode_s"] / (d["tokens"] - 1)
        del d["_arrive"], d["_first"]
    return per


def span_coverage(events, tid_prefix: str = "sched") -> float:
    """Fraction of the wall-clock window between the FIRST admission
    (earliest ``prefill`` lifecycle span start) and the LAST finish
    (latest lifecycle span end) that is covered by the union of the
    ``tid_prefix`` track's spans — the acceptance handle for "the trace
    accounts for where the time went" (>= 0.95 gated in the ``obs`` CI
    smoke and ``tests/test_obs.py``)."""
    window: List[float] = []
    spans: List[Tuple[float, float]] = []
    for ev in events:
        if ev.get("ph") != "X":
            continue
        tid = str(ev.get("tid", ""))
        if tid.startswith("rid"):
            if ev["name"] == "prefill":
                window.append(ev["ts"])
            window.append(ev["ts"] + ev["dur"])
        if tid.startswith(tid_prefix):
            spans.append((ev["ts"], ev["ts"] + ev["dur"]))
    if not window or not spans:
        return 0.0
    t0, t1 = min(window), max(window)
    if t1 <= t0:
        return 1.0
    covered, cur0, cur1 = 0.0, None, None
    for s, e in sorted((max(s, t0), min(e, t1)) for s, e in spans):
        if e <= s:
            continue
        if cur1 is None or s > cur1:
            covered += 0.0 if cur1 is None else cur1 - cur0
            cur0, cur1 = s, e
        else:
            cur1 = max(cur1, e)
    if cur1 is not None:
        covered += cur1 - cur0
    return covered / (t1 - t0)
