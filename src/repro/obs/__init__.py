"""Unified telemetry: metric registry, request-lifecycle tracer, and
optional device-profiler hooks.  See ``registry``/``trace``/``profiler``
module docstrings for the contracts; everything is pure host-side so
enabling any of it leaves model outputs bit-identical."""
from repro.obs import profiler
from repro.obs.registry import (
    Counter,
    Gauge,
    Histogram,
    MetricRegistry,
    Series,
    StatGroup,
    percentiles,
)
from repro.obs.trace import Tracer, derive_request_metrics, span_coverage

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricRegistry",
    "Series",
    "StatGroup",
    "Tracer",
    "derive_request_metrics",
    "percentiles",
    "profiler",
    "span_coverage",
]
