"""TrainState: the single pytree the training engine owns.

Everything a run needs to resume — parameters, optimizer state, the engine
step counter and the PRNG stream — travels through the jitted step as one
donated pytree, is sharded by one structurally-matched logical-spec tree
(see :func:`state_axes`) and is checkpointed as one file.

The RNG is stored as raw key *data* (uint32) rather than a typed key array
so the whole state round-trips through the .npz checkpointer; wrap with
``jax.random.wrap_key_data`` at use sites.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.distributed.sharding import is_axes  # noqa: F401  (re-export)
from repro.optim import OptState, make_optimizer

Axes = Tuple


class TrainState(NamedTuple):
    params: Any
    opt_state: OptState
    step: jax.Array          # engine-level step counter, scalar int32
    rng: jax.Array           # PRNG key data (uint32); (n, 2) when stacked


def new_train_state(params: Any, tc: TrainConfig, key: jax.Array, *,
                    stacked: bool = False) -> TrainState:
    """Fresh state around ``params``.

    ``stacked=True`` treats the leading param axis as the watershed/replica
    axis (paper Fig. 2a): optimizer state is built per replica and each
    replica gets its own PRNG stream.
    """
    opt_init, _ = make_optimizer(tc)
    if stacked:
        n = jax.tree.leaves(params)[0].shape[0]
        opt = jax.vmap(opt_init)(params)
        rng = jax.random.key_data(jax.random.split(key, n))
    else:
        # key_data ALIASES the caller's key buffer — copy, or the engine's
        # donated step would invalidate the caller's key array
        rng = jnp.array(jax.random.key_data(key))
        opt = opt_init(params)
    return TrainState(params=params, opt_state=opt,
                      step=jnp.zeros((), jnp.int32), rng=rng)


def advance_rng(rng: jax.Array) -> jax.Array:
    """Next key(s) in the per-state PRNG stream (key data in, key data out)."""
    def one(r):
        return jax.random.key_data(
            jax.random.fold_in(jax.random.wrap_key_data(r), 1))
    return jax.vmap(one)(rng) if rng.ndim == 2 else one(rng)


def state_axes(param_axes: Any, tc: TrainConfig, *,
               stacked: bool = False) -> TrainState:
    """Logical-axes tree structurally matching a TrainState.

    ``param_axes`` is the ParamFactory spec tree for ONE replica; in stacked
    mode every leaf gets a leading ``"batch"`` axis — the watershed axis,
    which the rule table maps onto ``("pod", "data")``.  Optimizer moments
    mirror the param axes, so fsdp/tensor-parallel placement of a weight
    automatically places its Adam state.
    """
    if stacked:
        param_axes = jax.tree.map(lambda ax: ("batch",) + tuple(ax),
                                  param_axes, is_leaf=is_axes)
    opt_step_ax = ("batch",) if stacked else ()
    nu_ax = param_axes if tc.optimizer == "adamw" else ()
    return TrainState(
        params=param_axes,
        opt_state=OptState(step=opt_step_ax, mu=param_axes, nu=nu_ax),
        step=(),
        rng=("batch", None) if stacked else (None,))


