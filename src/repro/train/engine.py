"""The unified mesh-sharded training engine.

One :class:`Engine` instance owns everything the three formerly hand-rolled
jit loops (launch/train.py x2, core/domst.py) each reimplemented:

  * the logical-axis rule tables from ``distributed/sharding.py`` —
    activation rules plus the ``fsdp=True`` parameter-rule variant when
    ``tc.fsdp`` is set — resolved into ``in_shardings``/``out_shardings``
    for the whole :class:`TrainState`;
  * buffer donation of the state through the jitted step;
  * gradient accumulation over ``accum_steps`` microbatches via
    ``jax.lax.scan`` (grads accumulate in fp32, metrics are averaged);
  * the stacked/IP-D multi-replica mode (paper Fig. 2a): the step body is
    ``vmap``-ped over a leading watershed axis that the rule table shards
    over ``("pod", "data")``;
  * checkpoint save/restore of the full state.

The engine is model-agnostic: it takes ``loss_fn(params, batch) ->
(loss, metrics)`` plus the ParamFactory spec tree and per-input logical
batch axes.  ``Engine.for_domst`` / ``Engine.for_lm`` bind the two drive
paths the paper measures.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Mapping, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro import checkpoint as ckpt
from repro.configs.base import ModelConfig, TrainConfig
from repro.obs import profiler
from repro.distributed.sharding import (
    logical_sharding, make_rules, resolve_pspec, tree_shardings,
)
from repro.optim import OptState, make_optimizer
from repro.train.state import (
    TrainState, advance_rng, new_train_state, state_axes,
)

LossFn = Callable[[Any, Dict[str, jax.Array]], Any]


def accumulate_grads(loss_fn: LossFn, params: Any,
                     batch: Dict[str, jax.Array], accum: int):
    """(grads, loss, metrics) for one macrostep of ``loss_fn``.

    ``accum > 1`` splits the leading batch dim into microbatches and scans
    ``value_and_grad`` over them: the activation live-set shrinks by the
    accumulation factor, grads and metrics accumulate in fp32 and are
    averaged.  The single shared implementation behind both the Engine and
    ``launch/steps.py``'s lowered step.
    """
    vg = jax.value_and_grad(loss_fn, has_aux=True)
    if accum == 1:
        (loss, mets), grads = vg(params, batch)
        return grads, loss, mets
    micro = jax.tree.map(
        lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
        batch)
    m_struct = jax.eval_shape(lambda p, b: loss_fn(p, b)[1],
                              params, jax.tree.map(lambda x: x[0], micro))

    def body(carry, mb):
        gsum, lsum, msum = carry
        (loss, mets), g = vg(params, mb)
        gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
        msum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                            msum, mets)
        return (gsum, lsum + loss.astype(jnp.float32), msum), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32), m_struct)
    (gsum, lsum, msum), _ = jax.lax.scan(
        body, (g0, jnp.zeros((), jnp.float32), m0), micro)
    grads = jax.tree.map(lambda g: g / accum, gsum)
    mets = jax.tree.map(lambda m: m / accum, msum)
    return grads, lsum / accum, mets


class Engine:
    """Mesh-sharded, donated, microbatched training step factory."""

    def __init__(self, loss_fn: LossFn, tc: TrainConfig, *,
                 cfg: Optional[ModelConfig] = None,
                 mesh=None,
                 param_axes: Any = None,
                 batch_axes: Optional[Mapping[str, tuple]] = None,
                 accum_steps: Optional[int] = None,
                 stacked: bool = False,
                 donate: bool = True,
                 rules: Optional[dict] = None,
                 param_rules: Optional[dict] = None,
                 explicit_shardings: bool = True,
                 eval_fn: Optional[LossFn] = None):
        self.loss_fn = loss_fn
        self.eval_fn = eval_fn
        self.tc = tc
        self.cfg = cfg
        self.accum = int(accum_steps) if accum_steps else max(tc.grad_accum, 1)
        self.stacked = stacked
        self.donate = donate
        # mesh and rule tables are built LAZILY: with explicit_shardings
        # off they are never consumed, and constructing a mesh here would
        # touch jax device state before e.g. the dry-run launcher injects
        # its XLA_FLAGS device count (see launch/mesh.py)
        self._mesh = mesh
        self._rules = rules
        self._param_rules = param_rules
        self.param_axes = param_axes
        self.batch_axes = dict(batch_axes or {})
        # explicit_shardings=False -> plain jit (no in/out shardings, no
        # constraint context): inputs keep whatever sharding the caller
        # committed them with, exactly like the seed jit(vmap) steps
        self._explicit = explicit_shardings and param_axes is not None
        self._axes = (state_axes(param_axes, tc, stacked=stacked)
                      if param_axes is not None else None)
        self._opt_update = make_optimizer(tc)[1]
        self._jit_cache: dict = {}
        self._bs_cache: dict = {}
        self._wrap_rng: dict = {}

    @property
    def mesh(self):
        if self._mesh is None:
            from repro.launch.mesh import make_host_mesh
            self._mesh = make_host_mesh()
        return self._mesh

    @property
    def rules(self) -> dict:
        if self._rules is None:
            self._rules = make_rules(self.cfg, mesh=self.mesh)
        return self._rules

    @property
    def param_rules(self) -> dict:
        """The FSDP rule variant (embed over the data axes) for params and
        optimizer state when ``tc.fsdp``; activation/batch constraints
        always use the plain rules."""
        if self._param_rules is None:
            self._param_rules = (
                make_rules(self.cfg, mesh=self.mesh, fsdp=True)
                if self.tc.fsdp else self.rules)
        return self._param_rules

    # -- constructors for the two drive paths ------------------------------
    @classmethod
    def for_domst(cls, cfg: ModelConfig, tc: TrainConfig, *, mesh=None,
                  stacked: bool = False, accum_steps: Optional[int] = None,
                  donate: bool = True,
                  explicit_shardings: bool = True) -> "Engine":
        """Dom-ST flood engine (sequential or stacked/IP-D)."""
        from repro.core import domst
        return cls(lambda p, b: domst.loss_fn(p, cfg, b), tc, cfg=cfg,
                   mesh=mesh, param_axes=domst.param_specs(cfg),
                   batch_axes=domst.BATCH_AXES, stacked=stacked,
                   accum_steps=accum_steps, donate=donate,
                   explicit_shardings=explicit_shardings,
                   eval_fn=lambda p, b: domst.eval_metrics(p, cfg, b))

    @classmethod
    def for_lm(cls, cfg: ModelConfig, tc: TrainConfig, *, mesh=None,
               accum_steps: Optional[int] = None,
               donate: bool = True) -> "Engine":
        """Token-LM engine for any assigned architecture."""
        from repro.configs.base import INPUT_SHAPES
        from repro.launch.steps import batch_axes as lm_batch_axes
        from repro.models import transformer as tfm
        remat = tc.remat != "none"

        def lm_eval(p, b):
            loss, mets = tfm.lm_loss(p, cfg, b, remat=remat)
            return {"loss": loss, **mets}

        return cls(lambda p, b: tfm.lm_loss(p, cfg, b, remat=remat), tc,
                   cfg=cfg, mesh=mesh, param_axes=tfm.param_specs(cfg),
                   batch_axes=lm_batch_axes(cfg, INPUT_SHAPES["train_4k"]),
                   accum_steps=accum_steps, donate=donate, eval_fn=lm_eval)

    # -- state lifecycle ---------------------------------------------------
    def init_state(self, key: jax.Array, params: Any) -> TrainState:
        """Fresh TrainState around ``params``, placed on its shardings.

        The state takes OWNERSHIP of ``params``: the buffers are donated
        through the jitted step, so callers must not reuse the argument
        after the first ``step`` (pass a fresh init if they need a copy).
        """
        state = new_train_state(params, self.tc, key, stacked=self.stacked)
        if self._explicit:
            state = jax.device_put(state, self.state_shardings(state))
        return state

    def wrap(self, params: Any, opt_state: OptState) -> TrainState:
        """Adopt externally-managed (params, opt_state) into a TrainState
        (compat shim for the seed ``step(params, opt, batch)`` signature;
        such engines run with ``donate=False``).  The rng is derived from
        ``tc.seed`` once and cached — these callers own no rng stream."""
        n = jax.tree.leaves(params)[0].shape[0] if self.stacked else None
        rng = self._wrap_rng.get(n)
        if rng is None:
            key = jax.random.key(self.tc.seed)
            rng = (jax.random.key_data(jax.random.split(key, n))
                   if self.stacked else jnp.array(jax.random.key_data(key)))
            self._wrap_rng[n] = rng
        # copy the cached buffer: a donate=True engine would otherwise
        # delete it on the first step and crash the second wrap
        return TrainState(params, opt_state, jnp.zeros((), jnp.int32),
                          jnp.array(rng))

    def save(self, path: str, state: TrainState) -> None:
        ckpt.save(path, state)

    def restore(self, path: str, example: TrainState) -> TrainState:
        state = ckpt.restore(path, example)
        if self._explicit:
            state = jax.device_put(state, self.state_shardings(state))
        return state

    # -- sharding resolution -----------------------------------------------
    def _one(self, axes, value, rules):
        return NamedSharding(self.mesh, resolve_pspec(
            tuple(axes), jnp.shape(value), self.mesh, rules))

    def state_shardings(self, state: TrainState) -> TrainState:
        """NamedSharding tree matching ``state``; params/moments through the
        parameter rules, counters/rng through the activation rules."""
        ax = self._axes
        pr = self.param_rules
        p_sh = tree_shardings(ax.params, state.params, self.mesh, pr)
        mu_sh = tree_shardings(ax.params, state.opt_state.mu, self.mesh, pr)
        nu = state.opt_state.nu
        nu_sh = (tree_shardings(ax.params, nu, self.mesh, pr)
                 if nu != () else ())
        return TrainState(
            params=p_sh,
            opt_state=OptState(
                step=self._one(ax.opt_state.step, state.opt_state.step,
                               self.rules),
                mu=mu_sh, nu=nu_sh),
            step=self._one(ax.step, state.step, self.rules),
            rng=self._one(ax.rng, state.rng, self.rules))

    def param_shardings(self, params: Any) -> Any:
        """NamedSharding tree for the params subtree alone — the serve
        hand-off contract.  For a non-fsdp engine these are exactly the
        shardings ``repro.serve.InferenceEngine`` resolves for its
        InferenceState params, so ``from_train_state`` adopts the live
        buffers without a host round-trip (pinned by tests/test_serve.py);
        an fsdp engine's params re-gather shard-to-shard on device."""
        return tree_shardings(self._axes.params, params, self.mesh,
                              self.param_rules)

    def batch_shardings(self, batch: Dict[str, jax.Array]) -> Dict[str, Any]:
        key = tuple(sorted((k, tuple(jnp.shape(v))) for k, v in batch.items()))
        cached = self._bs_cache.get(key)
        if cached is not None:
            return cached
        out = {}
        for k, v in batch.items():
            axes = self.batch_axes.get(k, (None,) * jnp.ndim(v))
            if self.stacked:
                # leading watershed axis takes the "batch" (pod/data) rule;
                # the per-replica minibatch axis stays local
                axes = ("batch",) + tuple(None if a == "batch" else a
                                          for a in axes)
            out[k] = self._one(axes, v, self.rules)
        self._bs_cache[key] = out
        return out

    def place_batch(self, batch: Dict[str, Any]) -> Dict[str, jax.Array]:
        """``jax.device_put`` a host batch onto the mesh under the batch rule
        table — the ShardedLoader's placement hook, so arrays arrive at
        ``step``/``eval_step`` already laid out for ``in_shardings`` and the
        transfer can overlap compute from the prefetch thread."""
        if not self._explicit:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        return jax.device_put(dict(batch), self.batch_shardings(batch))

    # -- the step ----------------------------------------------------------
    def _step_fn(self, state: TrainState, batch: Dict[str, jax.Array]):
        def one(params, opt_state, b):
            grads, loss, mets = accumulate_grads(self.loss_fn, params, b,
                                                 self.accum)
            params, opt_state, om = self._opt_update(params, grads, opt_state)
            return params, opt_state, {**mets, **om, "loss": loss}

        fn = jax.vmap(one) if self.stacked else one
        params, opt_state, mets = fn(state.params, state.opt_state, batch)
        return TrainState(params, opt_state, state.step + 1,
                          advance_rng(state.rng)), mets

    def _get_jit(self, state, batch):
        key = tuple(sorted((k, tuple(jnp.shape(v)), str(v.dtype))
                           for k, v in batch.items()))
        jfn = self._jit_cache.get(key)
        if jfn is None:
            donate = (0,) if self.donate else ()
            if self._explicit:
                st_sh = self.state_shardings(state)
                jfn = jax.jit(self._step_fn,
                              in_shardings=(st_sh, self.batch_shardings(batch)),
                              out_shardings=(st_sh, None),
                              donate_argnums=donate)
            else:
                jfn = jax.jit(self._step_fn, donate_argnums=donate)
            self._jit_cache[key] = jfn
        return jfn

    def step(self, state: TrainState, batch: Dict[str, jax.Array]):
        """One (macro)step: ``(state, batch) -> (state, metrics)``.

        ``batch`` leaves must be jax/numpy arrays whose leading axis is the
        minibatch (stacked mode: [watershed, minibatch, ...]); the minibatch
        dim must divide ``accum_steps``.
        """
        if self.accum > 1:
            b0 = next(iter(batch.values()))
            mb = b0.shape[1] if self.stacked else b0.shape[0]
            if mb % self.accum:
                raise ValueError(
                    f"minibatch dim {mb} not divisible by "
                    f"accum_steps={self.accum}")
        jfn = self._get_jit(state, batch)
        # live only inside an open jax.profiler window (--profile-dir)
        with profiler.annotate("train.step"):
            if not self._explicit:
                return jfn(state, batch)
            with self.mesh, logical_sharding(self.mesh, self.rules):
                return jfn(state, batch)

    # -- periodic evaluation on the sharded state --------------------------
    def _eval_body(self, state: TrainState, batch: Dict[str, jax.Array]):
        fn = jax.vmap(self.eval_fn) if self.stacked else self.eval_fn
        return fn(state.params, batch)

    def eval_step(self, state: TrainState, batch: Dict[str, jax.Array]):
        """Held-out metrics on the LIVE sharded state: no state update, no
        donation, no host pull of params.  Stacked mode vmaps ``eval_fn``
        over the leading watershed axis, so e.g. the Dom-ST engine returns
        per-watershed NSE directly from the mesh."""
        if self.eval_fn is None:
            raise ValueError("engine was built without an eval_fn")
        key = ("eval",) + tuple(sorted((k, tuple(jnp.shape(v)), str(v.dtype))
                                       for k, v in batch.items()))
        jfn = self._jit_cache.get(key)
        if jfn is None:
            if self._explicit:
                jfn = jax.jit(self._eval_body,
                              in_shardings=(self.state_shardings(state),
                                            self.batch_shardings(batch)))
            else:
                jfn = jax.jit(self._eval_body)
            self._jit_cache[key] = jfn
        with profiler.annotate("train.eval_step"):
            if not self._explicit:
                return jfn(state, batch)
            with self.mesh, logical_sharding(self.mesh, self.rules):
                return jfn(state, batch)
