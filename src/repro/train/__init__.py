from repro.train.engine import Engine  # noqa: F401
from repro.train.state import (  # noqa: F401
    TrainState, advance_rng, new_train_state, state_axes,
)
