"""Serving launcher: batched prefill + decode loop with a request queue.

Demonstrates the inference side of the framework on CPU with a reduced
config; the identical step functions are what the dry-run lowers for the
production mesh (decode_32k / long_500k shapes).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --requests 8 --prompt-len 24 --gen 16
"""
from __future__ import annotations

import argparse
import json
import time
from dataclasses import dataclass, field
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.data.tokens import synthetic_token_batch
from repro.models import transformer as tfm


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    generated: List[int] = field(default_factory=list)


def serve(args) -> dict:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    if not cfg.supports_decode():
        raise SystemExit(f"{cfg.name} is encoder-only; no decode path")
    params = tfm.init(cfg, jax.random.key(args.seed))
    max_len = args.prompt_len + args.gen + (cfg.num_patches or 0)

    prefill = jax.jit(lambda p, b: tfm.prefill(p, cfg, b, max_len=max_len))
    decode = jax.jit(lambda p, c, b, pos: tfm.decode_step(p, cfg, b, c, pos))

    # request queue -> fixed-size batch (static shapes; pad with repeats)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    args.prompt_len).astype(np.int32))
            for i in range(args.requests)]
    B = args.batch_size
    t0 = time.perf_counter()
    done = []
    while reqs:
        batch_reqs = reqs[:B]
        reqs = reqs[B:]
        pad = B - len(batch_reqs)
        toks = np.stack([r.prompt for r in batch_reqs]
                        + [batch_reqs[-1].prompt] * pad)
        inputs = {"tokens": jnp.asarray(toks)}
        if cfg.family == "vlm":
            inputs["patches"] = jnp.zeros(
                (B, cfg.num_patches, cfg.frontend_dim), jnp.float32)
        logits, cache = prefill(params, inputs)
        pos = args.prompt_len + (cfg.num_patches or 0) - 1
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        for r, t in zip(batch_reqs, np.asarray(tok)[:, 0]):
            r.generated.append(int(t))
        for step in range(args.gen - 1):
            pos += 1
            logits, cache = decode(params, cache, {"tokens": tok},
                                   jnp.asarray(pos, jnp.int32))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            for r, t in zip(batch_reqs, np.asarray(tok)[:, 0]):
                r.generated.append(int(t))
        done.extend(batch_reqs)
    wall = time.perf_counter() - t0
    total_tokens = sum(len(r.generated) for r in done)
    out = {"arch": cfg.name, "requests": len(done),
           "tokens": total_tokens, "wall_s": round(wall, 3),
           "tok_per_s": round(total_tokens / wall, 1)}
    print(json.dumps(out))
    for r in done[:2]:
        print(f"req {r.rid}: {r.generated[:12]}...")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    serve(ap.parse_args())


if __name__ == "__main__":
    main()
