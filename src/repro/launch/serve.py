"""Serving launcher — engine-driven sharded inference with a trained
checkpoint hand-off.

Two drive modes, mirroring ``repro.launch.train`` on the inference side,
both running through ``repro/serve/``:

  * ``--arch domst*`` — autoregressive peak-discharge forecasting: the
    stacked multi-watershed params from a ``repro.launch.train --ckpt``
    file (params subtree only; optimizer moments are never instantiated)
    roll forward day by day over the held-out forcing windows via
    :class:`repro.serve.Forecaster`, reporting per-watershed NSE against
    observed discharge — the paper's headline serving workload;
  * any ``supports_decode()`` LM arch — continuous batching over an
    :class:`InferenceEngine`: a jitted donated prefill-insert per request
    (exact prompt length), one fused all-slot decode step per token, EOS /
    budget eviction with in-place slot reuse (``repro.serve.Scheduler``).
    The KV cache is PAGED by default (``--page-size``; 0 restores the
    contiguous slot-major baseline): a pool of fixed-size pages plus
    per-slot page tables sizes KV memory to live tokens (``--num-pages``)
    instead of slots * max_len, and ``--prefill-chunk N`` admits long
    prompts N tokens at a time interleaved with decode steps so admission
    never stalls in-flight requests.  ``--prefix-cache`` grows the pool
    into a refcounted radix cache — requests sharing a prompt prefix
    (``--shared-prefix``) prefill it once and later admissions map the
    cached pages by refcount bump — and ``--preempt`` absorbs bursts by
    swapping a victim slot's pages to host memory instead of deferring
    admission; greedy streams stay bit-identical under both.  The whole
    :class:`InferenceState`
    (params + cache pool + page tables + slot position counters) is
    sharded from the ``distributed/sharding.py`` rule tables, so the same
    script drives the production mesh (decode_32k / long_500k shapes)
    that the dry-run lowers.  ``--temperature/--top-k/--top-p/
    --rep-penalty/--sample-seed`` switch requests from greedy argmax to
    per-request seeded sampling (heterogeneous configs per request via
    ``--queue file.json``); sampled streams stay deterministic — and
    speculation stays lossless — because draw keys fold by absolute
    stream position (``repro/serve/sampling.py``).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --requests 8 --prompt-len 24 --gen 16
  PYTHONPATH=src python -m repro.launch.train --arch domst --ckpt c.npz \
      --watersheds 4 --days 200 && \
  PYTHONPATH=src python -m repro.launch.serve --arch domst --ckpt c.npz \
      --watersheds 4 --days 200
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get_config, smoke_variant
from repro.core import domst
from repro.data.pipeline import make_domst_windows, stacked_test_batch
from repro.models import transformer as tfm
from repro.obs import (
    MetricRegistry, Tracer, derive_request_metrics, percentiles, profiler,
)
from repro.serve import (
    Forecaster, InferenceEngine, ModelDrafter, NgramDrafter, Request,
    SamplingParams, Scheduler, stream_digest,
)


def _sampling(args, rid: int, over: dict = None) -> SamplingParams:
    """Per-request sampling config: CLI flags are the defaults, a queue
    entry may override any field.  Each request folds its rid into the
    seed so co-batched sampled streams are decorrelated yet the whole
    run stays reproducible from ``--sample-seed`` alone."""
    over = over or {}
    return SamplingParams(
        temperature=float(over.get("temperature", args.temperature)),
        top_k=int(over.get("top_k", args.top_k)),
        top_p=float(over.get("top_p", args.top_p)),
        rep_penalty=float(over.get("rep_penalty", args.rep_penalty)),
        seed=int(over.get("seed", args.sample_seed + rid)))


def load_queue(cfg, args) -> list:
    """``--queue file.json``: a JSON list of request dicts.  Each entry
    needs ``prompt`` (a token-id list) and may set ``max_new`` plus any
    :class:`SamplingParams` field (``temperature``/``top_k``/``top_p``/
    ``rep_penalty``/``seed``); unset fields inherit the CLI flags."""
    with open(args.queue) as f:
        entries = json.load(f)
    if not isinstance(entries, list):
        raise SystemExit(f"--queue {args.queue}: expected a JSON list")
    reqs = []
    for i, e in enumerate(entries):
        if "prompt" not in e:
            raise SystemExit(f"--queue entry {i}: missing 'prompt'")
        reqs.append(Request(
            rid=i, max_new=int(e.get("max_new", args.gen)),
            prompt=np.asarray(e["prompt"], np.int32),
            sampling=_sampling(args, i, e)))
    return reqs


def make_requests(cfg, args) -> list:
    """Deterministic synthetic request queue (ragged lengths if asked).

    ``--shared-prefix N`` makes the first N tokens of every prompt
    identical — the shared-system-prompt traffic shape the prefix cache
    serves (per-request tails stay distinct and random)."""
    if args.queue:
        return load_queue(cfg, args)
    rng = np.random.default_rng(args.seed)
    sp = max(0, min(getattr(args, "shared_prefix", 0), args.prompt_len - 1))
    prefix = rng.integers(0, cfg.vocab_size, sp).astype(np.int32)
    reqs = []
    for i in range(args.requests):
        n = args.prompt_len
        if args.ragged:
            n = max(4, args.prompt_len - (i % 4) * 2)
        extras = {}
        if cfg.family == "vlm":
            extras["patches"] = np.zeros(
                (cfg.num_patches, cfg.frontend_dim), np.float32)
        tail = rng.integers(0, cfg.vocab_size,
                            max(1, n - sp)).astype(np.int32)
        reqs.append(Request(
            rid=i, max_new=args.gen, extras=extras,
            prompt=np.concatenate([prefix, tail]) if sp else tail,
            sampling=_sampling(args, i)))
    return reqs


def make_drafter(args, cfg, engine):
    """The --drafter policy: checkpoint-free prompt lookup, or a second
    smaller model whose own paged cache rides the target's mesh."""
    if not engine.paged:
        raise SystemExit("--spec-k > 0 requires the paged cache "
                         "(--page-size > 0); --spec-k 0 on the contiguous "
                         "layout is the parity baseline")
    if args.drafter == "ngram":
        return NgramDrafter()
    draft_cfg = get_config(args.draft_config or args.arch)
    if args.smoke:
        draft_cfg = smoke_variant(draft_cfg)
    if draft_cfg.vocab_size != cfg.vocab_size:
        raise SystemExit(
            f"draft model {draft_cfg.name} (vocab {draft_cfg.vocab_size}) "
            f"must share the target vocab ({cfg.vocab_size})")
    kw = dict(mesh=engine.mesh, slots=engine.slots,
              max_len=engine.max_len + args.spec_k,
              page_size=engine.page_size, seed=args.seed + 1)
    if args.draft_ckpt:
        return ModelDrafter.from_checkpoint(draft_cfg, args.draft_ckpt, **kw)
    return ModelDrafter(draft_cfg, **kw)


def serve_lm(args) -> dict:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    if not cfg.supports_decode():
        raise SystemExit(f"{cfg.name} is encoder-only; no decode path")
    params = tfm.init(cfg, jax.random.key(args.seed))
    reqs = make_requests(cfg, args)
    max_len = args.max_len or max(
        len(r.prompt) + r.max_new + (cfg.num_patches or 0) for r in reqs)
    engine = InferenceEngine(cfg, slots=args.batch_size, max_len=max_len,
                             paged=args.page_size > 0,
                             page_size=args.page_size or 16,
                             num_pages=args.num_pages or None,
                             prefill_chunk=args.prefill_chunk)
    if args.ckpt:
        params = engine.restore_params(args.ckpt, params)
    state = engine.init_state(params)
    drafter = make_drafter(args, cfg, engine) if args.spec_k else None
    if (args.prefix_cache or args.preempt) and not engine.paged:
        raise SystemExit("--prefix-cache/--preempt are page-pool policies; "
                         "they require the paged cache (--page-size > 0)")
    if args.host_cache_mb and not args.prefix_cache:
        raise SystemExit("--host-cache-mb is a spill tier FOR the prefix "
                         "cache; it requires --prefix-cache")
    registry = MetricRegistry()
    tracer = Tracer()
    sched = Scheduler(engine, state,
                      eos_id=args.eos if args.eos >= 0 else None,
                      spec_k=args.spec_k, drafter=drafter,
                      prefix_cache=args.prefix_cache, preempt=args.preempt,
                      host_cache_bytes=int(args.host_cache_mb * 2 ** 20),
                      registry=registry, tracer=tracer)
    t0 = time.perf_counter()
    with profiler.profile(args.profile_dir):
        generated = sched.run(reqs)
    wall = time.perf_counter() - t0
    total_tokens = sum(len(g) for g in generated.values())
    st = sched.stats
    # per-request latency percentiles derived FROM the lifecycle spans —
    # the legacy sched.ttft dict agrees to float precision (tests pin the
    # 1 ms acceptance bound), so there is exactly one timing source
    per_req = derive_request_metrics(tracer.events())
    ttft_vals = [m["ttft_s"] for m in per_req.values()]
    ttft_pct = percentiles(ttft_vals) if ttft_vals \
        else {"p50": 0.0, "p99": 0.0}
    gap_p99 = sched.decode_gaps.quantile(99) \
        if sched.decode_gaps.count else 0.0
    registry.gauge("serve.tok_per_s").set(total_tokens / wall)
    registry.gauge("serve.wall_s").set(wall)
    out = {"arch": cfg.name, "requests": len(generated),
           "tokens": total_tokens, "wall_s": round(wall, 3),
           "tok_per_s": round(total_tokens / wall, 1),
           "prefill_tok_per_s": round(
               st["prefill_tokens"] / max(st["prefill_s"], 1e-9), 1),
           "decode_tok_per_s": round(
               st["decode_tokens"] / max(st["decode_s"], 1e-9), 1),
           "paged": engine.paged, "page_size": engine.page_size,
           "num_pages": engine.num_pages,
           "prefill_chunk": engine.prefill_chunk,
           "prefill_chunks": st["prefill_chunks"],
           "spec_k": args.spec_k,
           "drafter": args.drafter if args.spec_k else None,
           "spec_steps": st["spec_steps"],
           "spec_proposed": st["spec_proposed"],
           "spec_accepted": st["spec_accepted"],
           # per SLOT-step: 1.0 means one token per fused step per slot
           # (the non-speculative rate); >1 means accepted drafts
           "accepted_tok_per_step": round(
               st["decode_tokens"] / max(st["decode_slot_steps"], 1), 3),
           "sampled_requests": sum(
               1 for r in reqs if not r.sampling.greedy),
           "temperature": args.temperature, "top_k": args.top_k,
           "top_p": args.top_p, "rep_penalty": args.rep_penalty,
           "sample_seed": args.sample_seed,
           # order-independent digest of every emitted stream: two runs of
           # the same (queue, params, seeds) must print the same digest —
           # the reproducibility handle the CI smoke greps
           "stream_digest": stream_digest(generated),
           "prefix_cache": args.prefix_cache, "preempt": args.preempt,
           "shared_prefix": args.shared_prefix,
           "prefix_hits": st["prefix_hits"],
           "prefix_hit_tokens": st["prefix_hit_tokens"],
           # hit tokens over all prefill-bound tokens (inserted + skipped):
           # the fraction of prompt prefill the cache absorbed
           "prefix_hit_rate": round(
               st["prefix_hit_tokens"] / max(
                   st["prefix_hit_tokens"] + st["prefill_tokens"], 1), 3),
           # prompt prefill skipped outright, over the queue's total
           # prompt tokens — the acceptance handle for the host tier
           # (a forced-spill queue reads 0 here without it)
           "prefill_skipped_pct": round(
               100.0 * st["prefix_hit_tokens"]
               / max(sum(len(r.prompt) for r in reqs), 1), 2),
           "host_cache_mb": args.host_cache_mb,
           "host_hits": st["host_hits"],
           "host_hit_tokens": st["host_hit_tokens"],
           "host_restored_pages": st["host_restored_pages"],
           "host_spilled_pages": st["host_spilled_pages"],
           "host_evicted_pages": st["host_evicted_pages"],
           "cow_pages": st["cow_pages"],
           "preemptions": st["preemptions"], "restores": st["restores"],
           "deferred_admissions": st["deferred_admissions"],
           "max_defer_cycles": st["max_defer_cycles"],
           # span-derived latency percentiles (see per_req above) and the
           # decode-gap distribution tail — the stall metric; the old
           # max_decode_gap_s scalar is this histogram's p100
           "ttft_p50_s": round(ttft_pct["p50"], 6),
           "ttft_p99_s": round(ttft_pct["p99"], 6),
           "decode_gap_p99_s": round(gap_p99, 6),
           "max_decode_gap_s": round(st["max_decode_gap_s"], 6),
           "device_count": len(jax.devices())}
    print(json.dumps(out))
    for r in reqs[:2]:
        print(f"req {r.rid}: {r.generated[:12]}...")
    if args.trace_out:
        tracer.save(args.trace_out)
    if args.metrics_out:
        registry.dump_jsonl(args.metrics_out)
    return out


def serve_domst(args) -> dict:
    cfg = get_config(args.arch)
    windows = make_domst_windows(args.watersheds, args.days)
    params = domst.init_stacked(cfg, jax.random.key(args.seed), len(windows))
    if args.ckpt:
        # params subtree of the full TrainState the train launcher saved
        params = ckpt.restore_subtree(args.ckpt, params, prefix="params")
    fc = Forecaster(cfg)
    held = stacked_test_batch(windows)
    params = fc.place_params(params)
    jax.block_until_ready(fc(params, held)["qhat"])   # compile warmup, so
    t0 = time.perf_counter()                          # the rate is honest
    with profiler.profile(args.profile_dir):
        res = fc(params, held)
    nses = [round(float(x), 6) for x in np.asarray(res["nse"])]
    wall = time.perf_counter() - t0
    horizon = int(held["discharge"].shape[1])
    out = {"arch": cfg.name, "watersheds": len(windows),
           "horizon_days": horizon, "restored": bool(args.ckpt),
           "nse": nses, "mean_nse": round(float(np.mean(nses)), 6),
           "wall_s": round(wall, 3),
           "forecasts_per_s": round(len(windows) * horizon / wall, 1)}
    print(json.dumps(out))
    return out


def serve(args) -> dict:
    if args.arch.startswith("domst"):
        return serve_domst(args)
    return serve_lm(args)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4,
                    help="decode slots (continuous-batching width)")
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=0,
                    help="cache length (0 = prompt+gen+patches)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged KV cache page size in tokens "
                         "(0 = contiguous slot-major cache)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="page-pool size (0 = slots * ceil(max_len/page); "
                         "smaller pools size KV memory to live tokens)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="insert long prompts this many tokens at a time, "
                         "interleaved with decode steps (0 = whole-prompt "
                         "prefill; requires the paged cache)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: verify up to K drafted "
                         "tokens per slot per fused step (0 = off, the "
                         "parity baseline; requires the paged cache). "
                         "Greedy outputs are bit-identical either way.")
    ap.add_argument("--drafter", choices=("ngram", "model"), default="ngram",
                    help="draft policy: host prompt-lookup (checkpoint-"
                         "free) or a second smaller model (--draft-config)")
    ap.add_argument("--draft-config", default="",
                    help="arch name for --drafter model (default: --arch; "
                         "must share the target vocab)")
    ap.add_argument("--draft-ckpt", default="",
                    help="TrainState .npz for the draft model's params "
                         "(params subtree only, like --ckpt)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="refcounted radix prefix cache over the page "
                         "pool: admissions map cached shared-prefix pages "
                         "by refcount bump and resume prefill at the "
                         "divergence point (requires the paged cache). "
                         "Greedy streams are bit-identical either way.")
    ap.add_argument("--host-cache-mb", type=float, default=0.0,
                    help="host-memory spill tier for the prefix cache, in "
                         "MiB (0 = off): cached pages evicted under pool "
                         "pressure spill to host RAM and later matches "
                         "swap them back in instead of re-prefilling "
                         "(requires --prefix-cache)")
    ap.add_argument("--preempt", action="store_true",
                    help="page-aware preemption: on page exhaustion swap "
                         "the most recently admitted slot's pages to host "
                         "and restore them when pages return, instead of "
                         "deferring admission (requires the paged cache)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="make the first N prompt tokens identical across "
                         "the queue (the shared-system-prompt workload "
                         "the prefix cache serves)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax, the "
                         "default; > 0 samples from the scaled softmax)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k highest-probability tokens "
                         "before sampling (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling: keep the smallest probability "
                         "mass >= p (1.0 = off)")
    ap.add_argument("--rep-penalty", type=float, default=1.0,
                    help="divide the logits of already-seen tokens by "
                         "this factor (1.0 = off)")
    ap.add_argument("--sample-seed", type=int, default=0,
                    help="base PRNG seed for sampled requests; request i "
                         "draws with seed sample-seed + i, so one flag "
                         "reproduces the whole run bit for bit")
    ap.add_argument("--queue", default="",
                    help="JSON file with the request queue: a list of "
                         "{prompt: [ids], max_new?, temperature?, top_k?, "
                         "top_p?, rep_penalty?, seed?} — per-request "
                         "overrides of the sampling flags")
    ap.add_argument("--eos", type=int, default=-1,
                    help="token id ending a request early (-1 = off)")
    ap.add_argument("--ragged", action="store_true",
                    help="vary prompt lengths across the request queue")
    ap.add_argument("--ckpt", default="",
                    help="TrainState .npz from repro.launch.train; only the "
                         "params subtree is restored")
    ap.add_argument("--trace-out", default="",
                    help="write the run's request-lifecycle spans as "
                         "Chrome trace-event JSON (open the file in "
                         "ui.perfetto.dev or chrome://tracing)")
    ap.add_argument("--metrics-out", default="",
                    help="dump the metric registry as JSONL, one metric "
                         "per line (histograms carry count/sum/min/max/"
                         "mean/p50/p90/p99)")
    ap.add_argument("--profile-dir", default="",
                    help="open a jax.profiler trace window around the run, "
                         "writing device traces here; engine dispatch is "
                         "TraceAnnotation-scoped so host phases line up "
                         "with the device timeline")
    ap.add_argument("--watersheds", type=int, default=23,
                    help="domst: watershed count (must match the ckpt run)")
    ap.add_argument("--days", type=int, default=400,
                    help="domst: synthetic record length (must match)")
    ap.add_argument("--seed", type=int, default=0)
    serve(ap.parse_args())


if __name__ == "__main__":
    main()
