"""Training launcher.

Two drive modes, matching the paper's two layers of the system:

  * ``--arch domst*``  — multi-watershed Dom-ST training on the synthetic
    hydrology dataset with the paper's I.P. distribution (sequential or
    stacked/IP-D execution);
  * any assigned LM arch — reduced-variant (``--smoke``) or full-config
    token training on synthetic Zipf streams.

On this CPU container the mesh is 1x1; the same script drives the
production mesh on real hardware (``--mesh pod|multipod``).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch domst --watersheds 4 --epochs 3
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke --steps 20
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import TrainConfig, get_config, smoke_variant
from repro.core import domst
from repro.data.pipeline import InputPipeline, make_training_windows, train_test_split
from repro.data.synthetic_hydro import generate_all_watersheds
from repro.data.tokens import synthetic_token_batch
from repro.metrics import Meter
from repro.models import transformer as tfm
from repro.optim import make_optimizer


def train_domst(args) -> dict:
    cfg = get_config(args.arch)
    tc = TrainConfig(learning_rate=args.lr, total_steps=args.steps or 2000,
                     warmup_steps=50)
    data = generate_all_watersheds(args.watersheds, num_days=args.days)
    windows = [make_training_windows(w) for w in data.values()]
    ip = InputPipeline(windows, batch_size=args.batch_size, seed=args.seed)
    meter = Meter()

    if args.mode == "stacked":          # IP-D: all watersheds per step
        params = domst.init_stacked(cfg, jax.random.key(args.seed),
                                    len(windows))
        opt_init, _ = make_optimizer(tc)
        opt = jax.vmap(opt_init)(params)
        step = domst.make_stacked_train_step(cfg, tc)
        for epoch in range(args.epochs):
            for batch in ip.stacked_batches(epoch):
                b = {k: jnp.asarray(v) for k, v in batch.items()}
                params, opt, m = step(params, opt, b)
            meter.update(loss=float(jnp.mean(m["loss"])))
            print(f"epoch {epoch} mean loss {meter.last('loss'):.4f} "
                  f"({meter.elapsed():.1f}s)", flush=True)
    else:                               # sequential: one watershed at a time
        step = domst.make_train_step(cfg, tc)
        opt_init, _ = make_optimizer(tc)
        all_params = []
        for w in windows:
            params = domst.init(cfg, jax.random.fold_in(
                jax.random.key(args.seed), w.watershed_id))
            opt = opt_init(params)
            for epoch in range(args.epochs):
                for batch in ip.batches(w, epoch):
                    b = {k: jnp.asarray(v) for k, v in batch.items()}
                    params, opt, m = step(params, opt, b)
            all_params.append(params)
            print(f"watershed {w.watershed_id} loss {float(m['loss']):.4f} "
                  f"({meter.elapsed():.1f}s)", flush=True)
        params = all_params

    # evaluate NSE per watershed
    nses = []
    plist = (params if isinstance(params, list)
             else [jax.tree.map(lambda x, i=i: x[i], params)
                   for i in range(len(windows))])
    for p, w in zip(plist, windows):
        _, te = train_test_split(w)
        ev = domst.evaluate(p, cfg, {k: jnp.asarray(v) for k, v in te.items()})
        nses.append(float(ev["nse"]))
    result = {"arch": args.arch, "mode": args.mode,
              "mean_nse": float(np.mean(nses)), "nse": nses,
              "wall_s": meter.elapsed()}
    print(json.dumps(result, indent=2))
    if args.ckpt:
        ckpt.save(args.ckpt, plist[0])
        print("saved", args.ckpt)
    return result


def train_lm(args) -> dict:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    tc = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                     warmup_steps=max(args.steps // 10, 1), remat="block")
    params = tfm.init(cfg, jax.random.key(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params")
    opt_init, opt_update = make_optimizer(tc)
    opt = opt_init(params)

    @jax.jit
    def step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: tfm.lm_loss(p, cfg, batch), has_aux=True)(params)
        params, opt, om = opt_update(params, grads, opt)
        return params, opt, {**metrics, **om, "loss": loss}

    meter = Meter()
    losses = []
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in synthetic_token_batch(
            cfg, args.batch_size, args.seq_len, seed=args.seed + i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
        if i % max(args.steps // 10, 1) == 0:
            print(f"step {i:5d} loss {losses[-1]:.4f} "
                  f"({meter.elapsed():.1f}s)", flush=True)
    result = {"arch": cfg.name, "first_loss": losses[0],
              "last_loss": losses[-1], "wall_s": meter.elapsed()}
    print(json.dumps(result))
    if args.ckpt:
        ckpt.save(args.ckpt, params)
        print("saved", args.ckpt)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--watersheds", type=int, default=23)
    ap.add_argument("--days", type=int, default=400)
    ap.add_argument("--mode", choices=("stacked", "sequential"),
                    default="stacked")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()
    if args.arch.startswith("domst"):
        train_domst(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
