"""Training launcher.

Two drive modes, matching the paper's two layers of the system, both
running through the unified mesh-sharded engine (``repro/train/``):

  * ``--arch domst*``  — multi-watershed Dom-ST training on the synthetic
    hydrology dataset with the paper's I.P. distribution (sequential or
    stacked/IP-D execution; the watershed axis shards over "pod"/"data");
  * any assigned LM arch — reduced-variant (``--smoke``) or full-config
    token training on synthetic Zipf streams.

The engine resolves param/opt/batch shardings from the logical-axis rule
tables, donates the TrainState through the jitted step, and microbatches
when ``--accum-steps k`` > 1.  ``--ckpt``/``--resume`` round-trip the FULL
TrainState (params + optimizer moments + step counter + rng stream).

On this CPU container the default mesh is 1x1; the same script drives the
production mesh on real hardware (``--mesh pod|multipod``).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch domst --watersheds 4 --epochs 3
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch domst --mode stacked --accum-steps 4
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_config, smoke_variant
from repro.core import domst
from repro.data.pipeline import InputPipeline, make_training_windows, train_test_split
from repro.data.synthetic_hydro import generate_all_watersheds
from repro.data.tokens import synthetic_token_batch
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.metrics import Meter
from repro.models import transformer as tfm
from repro.train import Engine


def _as_jnp(batch) -> dict:
    return {k: jnp.asarray(v) for k, v in batch.items()}


def _make_mesh(name: str):
    if name == "host":
        return make_host_mesh()
    return make_production_mesh(multi_pod=name == "multipod")


def train_domst(args) -> dict:
    cfg = get_config(args.arch)
    tc = TrainConfig(learning_rate=args.lr, total_steps=args.steps or 2000,
                     warmup_steps=50, grad_accum=args.accum_steps)
    data = generate_all_watersheds(args.watersheds, num_days=args.days)
    windows = [make_training_windows(w) for w in data.values()]
    ip = InputPipeline(windows, batch_size=args.batch_size, seed=args.seed)
    meter = Meter()
    mesh = _make_mesh(args.mesh)

    if args.mode == "stacked":          # IP-D: all watersheds per step
        engine = Engine.for_domst(cfg, tc, mesh=mesh, stacked=True)
        state = engine.init_state(
            jax.random.key(args.seed),
            domst.init_stacked(cfg, jax.random.key(args.seed), len(windows)))
        epoch0 = 0
        if args.resume:
            state = engine.restore(args.resume, state)
            start = int(state.step)
            # continue the run, don't replay it: extend the schedule
            # horizon past the restored step (else post-warmup LR decays
            # to 0 immediately) and advance the epoch stream so the
            # shuffles yield unseen batch orderings
            epoch0 = start // max(ip.steps_per_epoch(), 1)
            tc = dataclasses.replace(tc, total_steps=start + tc.total_steps)
            engine = Engine.for_domst(cfg, tc, mesh=mesh, stacked=True)
        for epoch in range(epoch0, epoch0 + args.epochs):
            for batch in ip.stacked_batches(epoch):
                state, m = engine.step(state, _as_jnp(batch))
            meter.update(loss=float(jnp.mean(m["loss"])))
            print(f"epoch {epoch} mean loss {meter.last('loss'):.4f} "
                  f"({meter.elapsed():.1f}s)", flush=True)
        plist = [jax.tree.map(lambda x, i=i: x[i], state.params)
                 for i in range(len(windows))]
    else:                               # sequential: one watershed at a time
        if args.resume or args.ckpt:
            raise SystemExit(
                "--ckpt/--resume are not supported with --mode sequential "
                "(that mode trains one TrainState per watershed); use "
                "--mode stacked to checkpoint or resume a run")
        engine = Engine.for_domst(cfg, tc, mesh=mesh)
        plist = []
        for w in windows:
            key = jax.random.fold_in(jax.random.key(args.seed),
                                     w.watershed_id)
            state = engine.init_state(key, domst.init(cfg, key))
            for epoch in range(args.epochs):
                for batch in ip.batches(w, epoch):
                    state, m = engine.step(state, _as_jnp(batch))
            plist.append(state.params)
            print(f"watershed {w.watershed_id} loss {float(m['loss']):.4f} "
                  f"({meter.elapsed():.1f}s)", flush=True)

    # evaluate NSE per watershed
    nses = []
    for p, w in zip(plist, windows):
        _, te = train_test_split(w)
        ev = domst.evaluate(p, cfg, _as_jnp(te))
        nses.append(float(ev["nse"]))
    result = {"arch": args.arch, "mode": args.mode,
              "accum_steps": args.accum_steps,
              "mean_nse": float(np.mean(nses)), "nse": nses,
              "wall_s": meter.elapsed()}
    print(json.dumps(result, indent=2))
    if args.ckpt:                       # stacked only (guarded above)
        engine.save(args.ckpt, state)   # the full multi-replica TrainState
        print("saved", args.ckpt)
    return result


def train_lm(args) -> dict:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    tc = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                     warmup_steps=max(args.steps // 10, 1), remat="block",
                     grad_accum=args.accum_steps)
    mesh = _make_mesh(args.mesh)
    engine = Engine.for_lm(cfg, tc, mesh=mesh)
    params = tfm.init(cfg, jax.random.key(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params")
    state = engine.init_state(jax.random.key(args.seed), params)
    start = 0
    if args.resume:
        state = engine.restore(args.resume, state)
        start = int(state.step)
        # continue, don't replay: extend the schedule horizon past the
        # restored step (else the cosine/linear LR is already 0) and
        # offset the synthetic stream so resumed steps see fresh batches
        tc = dataclasses.replace(tc, total_steps=start + args.steps)
        engine = Engine.for_lm(cfg, tc, mesh=mesh)

    meter = Meter()
    losses = []
    for i in range(args.steps):
        batch = _as_jnp(synthetic_token_batch(
            cfg, args.batch_size, args.seq_len, seed=args.seed + start + i))
        state, m = engine.step(state, batch)
        losses.append(float(m["loss"]))
        if i % max(args.steps // 10, 1) == 0:
            print(f"step {i:5d} loss {losses[-1]:.4f} "
                  f"({meter.elapsed():.1f}s)", flush=True)
    result = {"arch": cfg.name, "first_loss": losses[0],
              "last_loss": losses[-1], "steps": int(state.step),
              "wall_s": meter.elapsed()}
    print(json.dumps(result))
    if args.ckpt:
        engine.save(args.ckpt, state)
        print("saved", args.ckpt)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--watersheds", type=int, default=23)
    ap.add_argument("--days", type=int, default=400)
    ap.add_argument("--mode", choices=("stacked", "sequential"),
                    default="stacked")
    ap.add_argument("--mesh", choices=("host", "pod", "multipod"),
                    default="host",
                    help="host: 1x1 CPU mesh; pod/multipod: the production "
                         "TPU meshes (need 256/512 devices)")
    ap.add_argument("--accum-steps", type=int, default=1,
                    help="gradient-accumulation microbatches per step")
    ap.add_argument("--ckpt", default="",
                    help="save the full TrainState here after training")
    ap.add_argument("--resume", default="",
                    help="restore a TrainState checkpoint before training")
    args = ap.parse_args()
    if args.arch.startswith("domst"):
        train_domst(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
