"""Training launcher.

Two drive modes, matching the paper's two layers of the system, both
running through the unified mesh-sharded engine (``repro/train/``) and
fed by the async sharded input pipeline (``repro/data/loader.py``, the
paper's I.P. in Fig. 2a):

  * ``--arch domst*``  — multi-watershed Dom-ST training on the synthetic
    hydrology dataset with the paper's I.P. distribution (sequential or
    stacked/IP-D execution; the watershed axis shards over "pod"/"data");
  * any assigned LM arch — reduced-variant (``--smoke``) or full-config
    token training on synthetic Zipf streams.

The engine resolves param/opt/batch shardings from the logical-axis rule
tables, donates the TrainState through the jitted step, and microbatches
when ``--accum-steps k`` > 1.  The :class:`ShardedLoader` prefetches
``--prefetch`` batches ahead on a background thread (device_put under the
same rule tables), so the step never waits on host windowing; every
``--eval-interval`` steps the engine evaluates the live sharded state on a
held-out source (``Engine.eval_step`` — per-watershed NSE for Dom-ST,
held-out loss for LMs) without pulling params to host.

``--ckpt``/``--resume`` round-trip the FULL TrainState (params + optimizer
moments + step counter + rng stream); the restored step counter doubles as
the loader's stream cursor, so a resumed run continues the batch stream
exactly where it stopped — mid-epoch included, identically for the Dom-ST
and LM paths.  The same ``--ckpt`` file is the hand-off into serving:
``repro.launch.serve --ckpt`` restores just the params subtree into the
sharded inference engine (Dom-ST forecast or LM continuous batching)
without ever instantiating the optimizer moments.

On this CPU container the default mesh is 1x1; the same script drives the
production mesh on real hardware (``--mesh pod|multipod``).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch domst --watersheds 4 --epochs 3
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch domst --mode stacked --accum-steps 4
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_config, smoke_variant
from repro.core import domst
from repro.data.loader import ShardedLoader
from repro.data.pipeline import (
    InputPipeline, StackedSource, WatershedSource, make_domst_windows,
    stacked_test_batch, train_split, train_test_split,
)
from repro.data.tokens import TokenSource, synthetic_token_batch
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.metrics import Meter
from repro.models import transformer as tfm
from repro.obs import MetricRegistry, profiler
from repro.train import Engine

# held-out token batches are seeded far outside the training stream's
# ``seed + step`` range so eval data never aliases a training batch
EVAL_SEED_OFFSET = 2**31


def _make_mesh(name: str):
    if name == "host":
        return make_host_mesh()
    return make_production_mesh(multi_pod=name == "multipod")


def _timed(loader, registry: MetricRegistry):
    """Iterate ``loader`` while measuring, per step, the host-side wait
    for the next batch (``train.loader_wait_s`` — nonzero means the step
    outran the input pipeline's prefetch) and the wall-clock of the loop
    body (``train.step_s`` — dispatch plus whatever sync the body does).
    The last wait also lands in the ``train.loader_wait_last_s`` gauge."""
    wait_h = registry.histogram("train.loader_wait_s")
    step_h = registry.histogram("train.step_s")
    wait_g = registry.gauge("train.loader_wait_last_s")
    it = iter(loader)
    while True:
        t = time.perf_counter()
        try:
            batch = next(it)
        except StopIteration:
            return
        now = time.perf_counter()
        wait_h.record(now - t)
        wait_g.set(now - t)
        yield batch
        step_h.record(time.perf_counter() - now)


def _train_metrics(registry: MetricRegistry) -> dict:
    """Step-timing summary for the result JSON (empty before any step)."""
    step_h = registry.histogram("train.step_s")
    wait_h = registry.histogram("train.loader_wait_s")
    if not step_h.count:
        return {}
    return {"step_p50_s": round(step_h.quantile(50), 6),
            "step_p99_s": round(step_h.quantile(99), 6),
            "loader_wait_p99_s": round(wait_h.quantile(99), 6),
            "loader_wait_s": round(wait_h.sum, 6)}


def train_domst(args) -> dict:
    cfg = get_config(args.arch)
    tc = TrainConfig(learning_rate=args.lr, total_steps=args.steps or 2000,
                     warmup_steps=50, grad_accum=args.accum_steps)
    windows = make_domst_windows(args.watersheds, args.days)
    # train only on the leading split; the tail that eval_step scores
    # (stacked_test_batch / train_test_split) stays genuinely held out
    ip = InputPipeline([train_split(w) for w in windows],
                       batch_size=args.batch_size, seed=args.seed)
    registry = MetricRegistry()
    meter = Meter(registry=registry, prefix="train.")
    mesh = _make_mesh(args.mesh)
    if args.profile_dir:                # device-trace window over the run
        profiler.start(args.profile_dir)

    if args.mode == "stacked":          # IP-D: all watersheds per step
        engine = Engine.for_domst(cfg, tc, mesh=mesh, stacked=True)
        state = engine.init_state(
            jax.random.key(args.seed),
            domst.init_stacked(cfg, jax.random.key(args.seed), len(windows)))
        start = 0
        if args.resume:
            state = engine.restore(args.resume, state)
            start = int(state.step)
            # continue the run, don't replay it: the loader cursor picks
            # the shuffled stream back up at the restored step (mid-epoch
            # included) and the schedule horizon extends past it (else
            # post-warmup LR decays to 0 immediately)
            tc = dataclasses.replace(tc, total_steps=start + tc.total_steps)
            engine = Engine.for_domst(cfg, tc, mesh=mesh, stacked=True)
        source = StackedSource(ip)
        spe = source.steps_per_epoch
        held_out = engine.place_batch(stacked_test_batch(windows))
        loader = ShardedLoader(source, engine, prefetch=args.prefetch,
                               start_step=start,
                               num_steps=args.epochs * spe)
        for batch in _timed(loader, registry):
            state, m = engine.step(state, batch)
            step = loader.cursor
            if args.eval_interval and step % args.eval_interval == 0:
                ev = engine.eval_step(state, held_out)
                print(f"step {step} eval mean NSE "
                      f"{float(jnp.mean(ev['nse'])):.4f}", flush=True)
            if step % spe == 0:         # epoch boundary
                meter.update(loss=float(jnp.mean(m["loss"])))
                print(f"epoch {step // spe - 1} mean loss "
                      f"{meter.last('loss'):.4f} "
                      f"({meter.elapsed():.1f}s)", flush=True)
        ev = engine.eval_step(state, held_out)
        nses = [float(x) for x in np.asarray(ev["nse"])]
    else:                               # sequential: one watershed at a time
        if args.resume or args.ckpt:
            raise SystemExit(
                "--ckpt/--resume are not supported with --mode sequential "
                "(that mode trains one TrainState per watershed); use "
                "--mode stacked to checkpoint or resume a run")
        engine = Engine.for_domst(cfg, tc, mesh=mesh)
        nses = []
        for w, tw in zip(windows, ip.windows):   # tw: the train split of w
            key = jax.random.fold_in(jax.random.key(args.seed),
                                     w.watershed_id)
            state = engine.init_state(key, domst.init(cfg, key))
            source = WatershedSource(ip, tw)
            loader = ShardedLoader(
                source, engine, prefetch=args.prefetch,
                num_steps=args.epochs * source.steps_per_epoch)
            for batch in _timed(loader, registry):
                state, m = engine.step(state, batch)
            _, te = train_test_split(w)
            ev = engine.eval_step(state, engine.place_batch(te))
            nses.append(float(ev["nse"]))
            print(f"watershed {w.watershed_id} loss {float(m['loss']):.4f} "
                  f"nse {nses[-1]:.4f} ({meter.elapsed():.1f}s)", flush=True)

    if args.profile_dir:
        profiler.stop()
    result = {"arch": args.arch, "mode": args.mode,
              "accum_steps": args.accum_steps, "prefetch": args.prefetch,
              "mean_nse": float(np.mean(nses)), "nse": nses,
              "wall_s": meter.elapsed(), **_train_metrics(registry)}
    print(json.dumps(result, indent=2))
    if args.metrics_out:
        registry.dump_jsonl(args.metrics_out)
    if args.ckpt:                       # stacked only (guarded above)
        engine.save(args.ckpt, state)   # the full multi-replica TrainState
        print("saved", args.ckpt)
    return result


def train_lm(args) -> dict:
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    tc = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                     warmup_steps=max(args.steps // 10, 1), remat="block",
                     grad_accum=args.accum_steps)
    mesh = _make_mesh(args.mesh)
    engine = Engine.for_lm(cfg, tc, mesh=mesh)
    params = tfm.init(cfg, jax.random.key(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M params")
    state = engine.init_state(jax.random.key(args.seed), params)
    start = 0
    if args.resume:
        state = engine.restore(args.resume, state)
        start = int(state.step)
        # continue, don't replay: the loader resumes the token stream at
        # the restored step and the schedule horizon extends past it
        tc = dataclasses.replace(tc, total_steps=start + args.steps)
        engine = Engine.for_lm(cfg, tc, mesh=mesh)

    source = TokenSource(cfg, args.batch_size, args.seq_len, seed=args.seed)
    if args.eval_interval:
        held_out = engine.place_batch(synthetic_token_batch(
            cfg, args.batch_size, args.seq_len,
            seed=args.seed + EVAL_SEED_OFFSET))
    loader = ShardedLoader(source, engine, prefetch=args.prefetch,
                           start_step=start, num_steps=args.steps)
    registry = MetricRegistry()
    meter = Meter(registry=registry, prefix="train.")
    if args.profile_dir:                # device-trace window over the run
        profiler.start(args.profile_dir)
    losses = []
    for batch in _timed(loader, registry):
        state, m = engine.step(state, batch)
        losses.append(float(m["loss"]))
        i = loader.cursor - start - 1
        if args.eval_interval and loader.cursor % args.eval_interval == 0:
            ev = engine.eval_step(state, held_out)
            print(f"step {loader.cursor} eval loss "
                  f"{float(ev['loss']):.4f}", flush=True)
        if i % max(args.steps // 10, 1) == 0:
            print(f"step {i:5d} loss {losses[-1]:.4f} "
                  f"({meter.elapsed():.1f}s)", flush=True)
    if args.profile_dir:
        profiler.stop()
    result = {"arch": cfg.name, "first_loss": losses[0],
              "last_loss": losses[-1], "steps": int(state.step),
              "prefetch": args.prefetch, "wall_s": meter.elapsed(),
              **_train_metrics(registry)}
    print(json.dumps(result))
    if args.metrics_out:
        registry.dump_jsonl(args.metrics_out)
    if args.ckpt:
        engine.save(args.ckpt, state)
        print("saved", args.ckpt)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--watersheds", type=int, default=23)
    ap.add_argument("--days", type=int, default=400)
    ap.add_argument("--mode", choices=("stacked", "sequential"),
                    default="stacked")
    ap.add_argument("--mesh", choices=("host", "pod", "multipod"),
                    default="host",
                    help="host: 1x1 CPU mesh; pod/multipod: the production "
                         "TPU meshes (need 256/512 devices)")
    ap.add_argument("--accum-steps", type=int, default=1,
                    help="gradient-accumulation microbatches per step")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="loader prefetch depth (batches placed on the mesh "
                         "ahead of the step; 0 = synchronous host loop)")
    ap.add_argument("--eval-interval", type=int, default=0,
                    help="run Engine.eval_step on the held-out source every "
                         "N steps (0 = final eval only)")
    ap.add_argument("--ckpt", default="",
                    help="save the full TrainState here after training")
    ap.add_argument("--resume", default="",
                    help="restore a TrainState checkpoint before training "
                         "(the loader resumes the batch stream at its step)")
    ap.add_argument("--metrics-out", default="",
                    help="dump the metric registry as JSONL (per-step "
                         "timing histogram train.step_s, loader-wait "
                         "histogram/gauge, metered loss)")
    ap.add_argument("--profile-dir", default="",
                    help="open a jax.profiler trace window over the "
                         "training loop, writing device traces here; "
                         "Engine.step is TraceAnnotation-scoped")
    args = ap.parse_args()
    if args.arch.startswith("domst"):
        train_domst(args)
    else:
        train_lm(args)


if __name__ == "__main__":
    main()
