"""Step functions + input specs + sharding trees for launch/dryrun/train.

Everything here is mesh-agnostic until ``build_sharded_step`` binds a mesh
and rule table.  ``input_specs`` returns ShapeDtypeStruct stand-ins (weak-
type-correct, shardable, no device allocation) for every model input.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (
    INPUT_SHAPES, ModelConfig, ShapeConfig, TrainConfig,
)
from repro.distributed.sharding import (
    logical_sharding, make_rules, resolve_pspec, tree_pspecs,
)
from repro.models import transformer as tfm
from repro.optim import OptState, make_optimizer


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct, no allocation)
# ---------------------------------------------------------------------------
def batch_struct(cfg: ModelConfig, shape: ShapeConfig,
                 dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct
    if shape.kind == "train":
        if cfg.family == "audio":
            return {"frames": sd((B, S, cfg.frontend_dim), dtype),
                    "targets": sd((B, S), i32),
                    "loss_mask": sd((B, S), jnp.float32)}
        if cfg.family == "vlm":
            T = S - cfg.num_patches
            return {"patches": sd((B, cfg.num_patches, cfg.frontend_dim), dtype),
                    "tokens": sd((B, T), i32),
                    "targets": sd((B, T), i32)}
        return {"tokens": sd((B, S), i32), "targets": sd((B, S), i32)}
    if shape.kind == "prefill":
        if cfg.family == "audio":
            return {"frames": sd((B, S, cfg.frontend_dim), dtype)}
        if cfg.family == "vlm":
            return {"patches": sd((B, cfg.num_patches, cfg.frontend_dim), dtype),
                    "tokens": sd((B, S - cfg.num_patches), i32)}
        return {"tokens": sd((B, S), i32)}
    # decode: one new token against a seq_len cache
    return {"tokens": sd((B, 1), i32)}


def batch_axes(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, tuple]:
    """Logical axes per input (resolved to PartitionSpecs by the rules)."""
    if shape.kind == "train":
        if cfg.family == "audio":
            return {"frames": ("batch", "seq", None),
                    "targets": ("batch", "seq"),
                    "loss_mask": ("batch", "seq")}
        if cfg.family == "vlm":
            return {"patches": ("batch", "seq", None),
                    "tokens": ("batch", "seq"),
                    "targets": ("batch", "seq")}
        return {"tokens": ("batch", "seq"), "targets": ("batch", "seq")}
    if shape.kind == "prefill":
        if cfg.family == "audio":
            return {"frames": ("batch", "seq", None)}
        if cfg.family == "vlm":
            return {"patches": ("batch", "seq", None),
                    "tokens": ("batch", "seq")}
        return {"tokens": ("batch", "seq")}
    return {"tokens": ("batch", None)}


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    """All inputs for (cfg, shape): batch (+ cache/position for decode)."""
    shape = INPUT_SHAPES[shape_name]
    out: Dict[str, Any] = {"batch": batch_struct(cfg, shape)}
    if shape.kind == "decode":
        out["cache"] = jax.eval_shape(
            lambda: tfm.init_cache(cfg, shape.global_batch, shape.seq_len))
        out["position"] = jax.ShapeDtypeStruct((), jnp.int32)
    return out


# ---------------------------------------------------------------------------
# Step functions (pure; jit/sharding bound later)
# ---------------------------------------------------------------------------
def make_train_step_fn(cfg: ModelConfig, tc: TrainConfig):
    """Pure (params, opt, batch) train step; gradient accumulation over
    ``tc.grad_accum`` microbatches via the engine's shared scan."""
    from repro.train.engine import accumulate_grads
    _, opt_update = make_optimizer(tc)
    remat = tc.remat != "none"
    A = max(tc.grad_accum, 1)

    def train_step(params, opt_state, batch):
        grads, loss, metrics = accumulate_grads(
            lambda p, b: tfm.lm_loss(p, cfg, b, remat=remat),
            params, batch, A)
        params, opt_state, om = opt_update(params, grads, opt_state)
        return params, opt_state, {**metrics, **om, "loss": loss}

    return train_step


def make_prefill_step_fn(cfg: ModelConfig, max_len: int):
    if not cfg.supports_decode():
        # encoder "prefill" == full forward producing frame-level logits
        def encoder_step(params, batch):
            x, _ = tfm.forward(params, cfg, batch)
            from repro.models.layers import unembed
            return unembed(params["embed"], x, tie=cfg.tie_embeddings,
                           cap=cfg.logit_softcap, real_vocab=cfg.vocab_size)
        return encoder_step

    def prefill_step(params, batch):
        return tfm.prefill(params, cfg, batch, max_len=max_len)

    return prefill_step


def make_serve_step_fn(cfg: ModelConfig):
    def serve_step(params, cache, batch, position):
        return tfm.decode_step(params, cfg, batch, cache, position)
    return serve_step


# ---------------------------------------------------------------------------
# Sharding assembly
# ---------------------------------------------------------------------------
def param_pspecs(cfg: ModelConfig, mesh, rules):
    spec_tree = tfm.param_specs(cfg)
    shapes = jax.eval_shape(lambda: tfm.init(cfg, jax.random.key(0)))
    return tree_pspecs(spec_tree, shapes, mesh, rules), shapes


def opt_pspecs(pspecs, tc: TrainConfig):
    if tc.optimizer == "adamw":
        return OptState(step=P(), mu=pspecs, nu=pspecs)
    return OptState(step=P(), mu=pspecs, nu=())


def batch_pspecs(cfg: ModelConfig, shape: ShapeConfig, mesh, rules,
                 structs) -> Dict[str, P]:
    axes = batch_axes(cfg, shape)
    return {k: resolve_pspec(axes[k], structs[k].shape, mesh, rules)
            for k in structs}


def cache_pspecs(cfg: ModelConfig, cache_struct, mesh, rules):
    ax = tfm.cache_axes(cfg)
    return tree_pspecs(ax, cache_struct, mesh, rules)


def lower_step(cfg: ModelConfig, shape_name: str, mesh, *,
               tc: Optional[TrainConfig] = None,
               sequence_parallel: bool = False,
               serve_bf16: bool = False,
               extra_rules: Optional[dict] = None):
    """Build + lower the right step for (cfg, shape) on ``mesh``.

    Returns (lowered, kind).  ``.compile()`` on the result proves the
    distribution config is coherent (deliverable (e)).
    """
    shape = INPUT_SHAPES[shape_name]
    tc = tc or TrainConfig(remat="block")
    rules = make_rules(cfg, mesh=mesh, sequence_parallel=sequence_parallel)
    if extra_rules:
        rules.update(extra_rules)
    # params/optimizer may use the FSDP rule variant (embed dim over data);
    # activation constraints always use the plain rules
    prules = make_rules(cfg, mesh=mesh, fsdp=True) if tc.fsdp else rules
    pspecs, param_shapes = param_pspecs(cfg, mesh, prules)
    if serve_bf16 and shape.kind in ("prefill", "decode"):
        # serving checkpoints are bf16 (halves weight-resident HBM; the
        # model casts at use sites anyway)
        param_shapes = jax.tree.map(
            lambda st: jax.ShapeDtypeStruct(
                st.shape, jnp.bfloat16
                if jnp.issubdtype(st.dtype, jnp.floating) else st.dtype),
            param_shapes)
    specs = input_specs(cfg, shape_name)
    b_pspecs = batch_pspecs(cfg, shape, mesh, rules, specs["batch"])

    def ns(tree):
        """PartitionSpec tree -> NamedSharding tree (None passes through)."""
        return jax.tree.map(lambda p: NamedSharding(mesh, p), tree,
                            is_leaf=lambda x: isinstance(x, P))

    with mesh, logical_sharding(mesh, rules):
        if shape.kind == "train":
            ospecs = opt_pspecs(pspecs, tc)
            opt_shapes = jax.eval_shape(
                make_optimizer(tc)[0], param_shapes)
            fn = make_train_step_fn(cfg, tc)
            jfn = jax.jit(
                fn,
                in_shardings=(ns(pspecs), ns(ospecs), ns(b_pspecs)),
                out_shardings=(ns(pspecs), ns(ospecs), None),
                donate_argnums=(0, 1))
            lowered = jfn.lower(param_shapes, opt_shapes, specs["batch"])
            return lowered, "train"
        if shape.kind == "prefill":
            fn = make_prefill_step_fn(cfg, max_len=shape.seq_len)
            if cfg.supports_decode():
                cache_struct = jax.eval_shape(
                    lambda: tfm.init_cache(cfg, shape.global_batch,
                                           shape.seq_len))
                c_pspecs = cache_pspecs(cfg, cache_struct, mesh, rules)
                out_sh = (None, ns(c_pspecs))
            else:
                out_sh = None
            jfn = jax.jit(fn, in_shardings=(ns(pspecs), ns(b_pspecs)),
                          out_shardings=out_sh)
            lowered = jfn.lower(param_shapes, specs["batch"])
            return lowered, "prefill"
        # decode
        fn = make_serve_step_fn(cfg)
        c_pspecs = cache_pspecs(cfg, specs["cache"], mesh, rules)
        jfn = jax.jit(
            fn,
            in_shardings=(ns(pspecs), ns(c_pspecs), ns(b_pspecs),
                          NamedSharding(mesh, P())),
            out_shardings=(None, ns(c_pspecs)),
            donate_argnums=(1,))
        lowered = jfn.lower(param_shapes, specs["cache"], specs["batch"],
                            specs["position"])
        return lowered, "decode"
