import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# unroll layer/loss scans so cost_analysis & collective parsing see the
# whole program (XLA counts a while body once) — dry-run only.
os.environ.setdefault("REPRO_SCAN_UNROLL", "1")

"""Multi-pod dry-run (deliverable (e)) + roofline-term capture (g).

For every (architecture x input-shape x mesh) combination this lowers and
compiles the appropriate step (train_step / prefill_step / serve_step) for
the production mesh — (16,16) "data","model" single-pod and (2,16,16)
"pod","data","model" multi-pod — using ShapeDtypeStruct inputs (no
allocation), then records:

  * memory_analysis()      — proves the program fits per-device HBM
  * cost_analysis()        — HLO FLOPs / bytes for the roofline terms
  * collective bytes       — parsed from the post-GSPMD compiled HLO text
                             (all-gather / all-reduce / reduce-scatter /
                              all-to-all / collective-permute)

Results land in results/dryrun/<arch>__<shape>__<mesh>.json; EXPERIMENTS.md
§Dry-run / §Roofline and benchmarks/roofline.py read from there.

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k [--multipod]
  python -m repro.launch.dryrun --all [--multipod] [--jobs N]
"""
import argparse
import json
import re
import subprocess
import sys
import time
import traceback

import jax
import numpy as np

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.configs.base import TrainConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import lower_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "..", "..", "..", "results", "dryrun")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z]+[0-9]+|pred)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^=]*?\))|(?:[a-z]+[0-9]*\[[^\]]*\][^\s]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective type (result shapes)."""
    seen_done = set()
    out = {k: 0 for k in ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute")}
    counts = {k: 0 for k in out}
    for m in _COLL_RE.finditer(hlo_text):
        shape_txt, kind = m.group(1), m.group(2)
        # async pairs appear as -start/-done; -done result repeats the
        # buffer, so only count -start (or the sync form).
        if "-done(" in m.group(0):
            continue
        out[kind] += _shape_bytes(shape_txt)
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": int(sum(out.values()))}


def _compile(cfg, shape_name, mesh, tc, sequence_parallel,
             serve_bf16=False):
    t0 = time.time()
    lowered, kind = lower_step(cfg, shape_name, mesh, tc=tc,
                               sequence_parallel=sequence_parallel,
                               serve_bf16=serve_bf16)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    return lowered, compiled, kind, t_lower, time.time() - t0


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            sequence_parallel: bool = False, tag: str = "",
            fsdp: bool = False, accum: int = 1, serve_bf16: bool = False,
            out_dir: str = RESULTS_DIR) -> dict:
    cfg = get_config(arch)
    mesh_name = "multipod" if multi_pod else "pod"
    mesh = make_production_mesh(multi_pod=multi_pod)
    tc = TrainConfig(remat="block", fsdp=fsdp, grad_accum=accum)

    # Pass 1 — ROLLED layer scan: the production program; its
    # memory_analysis is the "fits in HBM" proof (while-loop buffers
    # are reused across layers).
    os.environ["REPRO_SCAN_UNROLL"] = "0"
    _, compiled_mem, kind, tl0, tc0 = _compile(
        cfg, shape_name, mesh, tc, sequence_parallel, serve_bf16)
    # Pass 2 — UNROLLED: same math, loops unrolled so cost_analysis and
    # the HLO collective sweep see every layer (XLA counts a while body
    # once).  Its temp size is NOT meaningful (no cross-layer reuse).
    # grad_accum is forced to 1 here: per-step FLOPs/collectives are
    # identical and the rolled accumulation loop would undercount.
    os.environ["REPRO_SCAN_UNROLL"] = "1"
    tc_cost = TrainConfig(remat="block", fsdp=fsdp, grad_accum=1)
    _, compiled_cost, _, tl1, tc1 = _compile(
        cfg, shape_name, mesh, tc_cost, sequence_parallel, serve_bf16)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "step_kind": kind, "tag": tag,
        "devices": int(np.prod(mesh.devices.shape)),
        "mesh_shape": list(mesh.devices.shape),
        "lower_s": round(tl0 + tl1, 2),
        "compile_s": round(tc0 + tc1, 2),
        "opts": {"fsdp": fsdp, "grad_accum": accum, "serve_bf16": serve_bf16,
                 "moe_shardmap": os.environ.get("REPRO_MOE_SHARDMAP", "1")},
    }
    try:
        ma = compiled_mem.memory_analysis()
        rec["memory"] = {
            k: int(getattr(ma, k)) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes")
            if hasattr(ma, k)}
        print("memory_analysis (rolled):", rec["memory"])
    except Exception as e:  # CPU backend may not implement it
        rec["memory"] = {"error": str(e)[:200]}
    try:
        ca = compiled_cost.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float)) and (
                           "flops" in k or "bytes" in k or "utilization" in k)}
        print("cost_analysis: flops=%.3e bytes=%.3e" % (
            rec["cost"].get("flops", -1), rec["cost"].get("bytes accessed", -1)))
    except Exception as e:
        rec["cost"] = {"error": str(e)[:200]}
    hlo = compiled_cost.as_text()
    rec["collectives"] = parse_collectives(hlo)
    rec["hlo_bytes"] = len(hlo)
    print("collectives:", rec["collectives"]["bytes"],
          "counts:", rec["collectives"]["counts"])

    os.makedirs(out_dir, exist_ok=True)
    name = f"{arch}__{shape_name}__{mesh_name}"
    if tag:
        name += f"__{tag}"
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(rec, f, indent=2)
    print(f"OK {name}  lower={rec['lower_s']}s compile={rec['compile_s']}s")
    return rec


def matrix(multi_pod_also: bool = True):
    """The full (arch x shape) baseline list, with documented skips."""
    combos = []
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for sname, shape in INPUT_SHAPES.items():
            if shape.kind == "decode" and not cfg.supports_decode():
                continue  # encoder-only: no decode step (DESIGN.md §5)
            if sname == "long_500k":
                if not cfg.supports_decode():
                    continue
                if not cfg.sub_quadratic():
                    if arch == "gemma2-2b":
                        combos.append(("gemma2-2b-localonly", sname))
                    continue  # full-attention arch: skip (DESIGN.md §5)
            combos.append((arch, sname))
    return combos


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--seqpar", action="store_true",
                    help="sequence-parallel activation rules (perf exp)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--servebf16", action="store_true")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--all", action="store_true",
                    help="run the full matrix in subprocesses")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    if args.list:
        for a, s in matrix():
            print(a, s)
        return

    if args.all:
        fails = []
        for a, s in matrix():
            for mp in ([False, True] if True else [False]):
                name = f"{a}__{s}__{'multipod' if mp else 'pod'}"
                path = os.path.join(args.out, name + ".json")
                if os.path.exists(path):
                    print("skip (done)", name)
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", a, "--shape", s, "--out", args.out]
                if mp:
                    cmd.append("--multipod")
                print(">>", " ".join(cmd), flush=True)
                try:
                    r = subprocess.run(cmd, timeout=2400)
                    code = r.returncode
                except subprocess.TimeoutExpired:
                    code = -9
                    print("TIMEOUT", name, flush=True)
                if code != 0:
                    fails.append(name)
        print("FAILURES:", fails if fails else "none")
        sys.exit(1 if fails else 0)

    run_one(args.arch, args.shape, multi_pod=args.multipod,
            sequence_parallel=args.seqpar, tag=args.tag,
            fsdp=args.fsdp, accum=args.accum, serve_bf16=args.servebf16,
            out_dir=args.out)


if __name__ == "__main__":
    main()
