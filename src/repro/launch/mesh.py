"""Production mesh construction (TPU v5e target).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required so smoke tests see 1 CPU device while
the dry-run sees 512 placeholder devices).
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(jax.devices())} — "
            "the dry-run launcher must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import")
    return jax.make_mesh(shape, axes, devices=devices)


def make_host_mesh():
    """1-device mesh for CPU smoke runs (same axis names, sizes 1x1)."""
    return jax.make_mesh((1, 1), ("data", "model"))
