from repro.distributed.sharding import (  # noqa: F401
    ParamFactory, constrain, logical_sharding, make_rules, resolve_pspec,
    tree_pspecs, tree_shardings,
)
