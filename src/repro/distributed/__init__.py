from repro.distributed.sharding import (  # noqa: F401
    ParamFactory, cache_needs_seq_shard, constrain, is_axes,
    logical_sharding, make_rules, resolve_pspec, tree_pspecs,
    tree_shardings,
)
