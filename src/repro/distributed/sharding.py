"""Logical-axis sharding rule engine.

The paper's two-level distribution strategy (watersheds -> nodes, CNN heads
-> devices) is generalized here as a logical-axis rule table:

  * ``batch``  -> the watershed / input-pipeline axis: ("pod", "data")
  * ``heads`` / ``kv_heads`` / ``experts`` / ``ffn`` / ``inner`` /
    ``pix_heads`` -> the head-partitioning axis: "model"
  * everything else (embed, seq, state, conv, ...) replicated.

Parameters are built through :class:`ParamFactory`, which can run in
``init`` mode (returns initialized arrays) or ``spec`` mode (returns the
logical-axes tuple), so a single ``params(cfg, mk)`` definition yields both
the param pytree and a structurally identical pytree of logical specs.

Rules resolve to :class:`jax.sharding.PartitionSpec`; a mesh-axis
assignment is dropped (replicated) whenever the dim is not divisible by the
mesh-axis size — the documented fallback for e.g. 24 heads on a 16-way
model axis (tp_mode="ffn" archs avoid relying on head sharding entirely).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Callable, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Tuple[Optional[str], ...]
MeshAxis = Union[None, str, Tuple[str, ...]]


def is_axes(x: Any) -> bool:
    """True for a logical-axes tuple leaf (the ParamFactory spec leaves) —
    the canonical ``is_leaf`` predicate for traversing spec trees."""
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------
def make_rules(cfg=None, *, mesh: Optional[Mesh] = None,
               tp_mode: Optional[str] = None,
               sequence_parallel: bool = False,
               fsdp: bool = False) -> dict[str, MeshAxis]:
    """Logical axis -> mesh axis assignment.

    ``cfg`` (a ModelConfig) supplies ``tp_mode``; ``mesh`` determines whether
    a "pod" axis exists.  ``sequence_parallel`` additionally shards the
    ``seq`` activation axis over "model" (a beyond-paper optimization used
    in the §Perf hillclimbs).  ``fsdp=True`` is the PARAMETER rule variant:
    the ``embed`` dim of params/optimizer state shards over the data axes
    (ZeRO-3-style; GSPMD inserts the weight all-gathers) — use it for the
    param/opt trees only, never for activation constraints.
    """
    tp = tp_mode or (getattr(cfg, "tp_mode", None) or "heads")
    axis_names = tuple(mesh.axis_names) if mesh is not None else ("data", "model")
    batch: MeshAxis = tuple(a for a in ("pod", "data") if a in axis_names) or None
    if isinstance(batch, tuple) and len(batch) == 1:
        batch = batch[0]
    model = "model" if "model" in axis_names else None

    rules: dict[str, MeshAxis] = {
        "batch": batch,
        "seq": model if sequence_parallel else None,
        "embed": batch if fsdp else None,
        "heads": model if tp == "heads" else None,
        "kv_heads": model if tp == "heads" else None,
        "head_dim": None,
        "ffn": model,
        "vocab": model,
        "experts": model,
        "inner": model,          # ssm / rglru channel dim
        "state": None,
        "conv": None,
        "pix_heads": model,      # Dom-ST spatial heads (the paper's partition)
        "pixels": None,
        "time": None,
        "hidden": model,         # lstm / mlp hidden
        # decode KV-cache sequence axis: sharded over model whenever the KV
        # heads can't shard there (ffn-mode archs, or kv_heads % ways != 0)
        # so a 32k cache never replicates 16x.
        "cache_seq": model if cache_needs_seq_shard(cfg, mesh, tp) else None,
        # paged-KV page pool (serve): the page axis distributes over the
        # batch axes like request slots did, while the within-page offset
        # axis reuses "cache_seq" above — so both cache_needs_seq_shard
        # branches carry over to the paged layout unchanged.
        "pages": batch,
    }
    return rules


def cache_needs_seq_shard(cfg, mesh, tp_mode: Optional[str] = None) -> bool:
    """True when the decode KV cache must shard its SEQUENCE axis.

    The head axes of a ``ffn``-mode arch (or one whose kv_heads don't
    divide the model axis) can't shard over "model", so the cache would
    replicate model-ways times; ``make_rules`` then routes ``cache_seq``
    onto "model" instead.  Public so the serve engine and its mesh tests
    can assert which branch a config takes."""
    tp = tp_mode or (getattr(cfg, "tp_mode", None) or "heads")
    if tp == "ffn":
        return True
    if cfg is None or mesh is None:
        return False
    kv = getattr(cfg, "num_kv_heads", 0)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ways = sizes.get("model", 1)
    return bool(kv) and kv % ways != 0


# back-compat alias (pre-PR-3 tests import the underscored name)
_cache_needs_seq_shard = cache_needs_seq_shard


def resolve_pspec(axes: Axes, shape: Sequence[int], mesh: Mesh,
                  rules: Mapping[str, MeshAxis]) -> P:
    """Map a logical-axes tuple to a PartitionSpec, dropping indivisible axes."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    entries: list[MeshAxis] = []
    for dim, ax in zip(shape, axes):
        assignment = rules.get(ax) if ax is not None else None
        if assignment is None:
            entries.append(None)
            continue
        names = assignment if isinstance(assignment, tuple) else (assignment,)
        total = int(np.prod([sizes[n] for n in names]))
        if dim % total == 0:
            entries.append(assignment)
        else:
            # jit argument shardings require exact divisibility (GSPMD's
            # uneven padding is not allowed at the pjit boundary), so
            # indivisible dims replicate.  Archs whose head counts don't
            # divide the model axis use tp_mode="ffn"; vocabs are padded
            # to multiples of 128 (configs/base.py padded_vocab).
            entries.append(None)

    # PartitionSpec forbids reusing a mesh axis across dims
    seen: set[str] = set()
    final: list[MeshAxis] = []
    for e in entries:
        names = e if isinstance(e, tuple) else (e,) if e else ()
        if any(n in seen for n in names):
            final.append(None)
        else:
            final.append(e)
            seen.update(names)
    return P(*final)


# ---------------------------------------------------------------------------
# ParamFactory: one definition -> params AND specs
# ---------------------------------------------------------------------------
class ParamFactory:
    """Builds parameters (``mode='init'``) or logical-axis specs (``mode='spec'``).

    Keys are derived deterministically from a root key and a call counter,
    so init/spec traversals stay structurally aligned.
    """

    def __init__(self, key: Optional[jax.Array] = None, mode: str = "init",
                 dtype: Any = jnp.float32):
        assert mode in ("init", "spec")
        self.mode = mode
        self.key = key
        self.dtype = dtype
        self._n = 0

    def _next_key(self) -> jax.Array:
        k = jax.random.fold_in(self.key, self._n)
        self._n += 1
        return k

    def __call__(self, shape: Sequence[int], axes: Axes,
                 init: str = "normal", scale: Optional[float] = None) -> Any:
        shape = tuple(int(s) for s in shape)
        assert len(shape) == len(axes), (shape, axes)
        if self.mode == "spec":
            self._n += 1
            return tuple(axes)
        k = self._next_key()
        if init == "zeros":
            return jnp.zeros(shape, self.dtype)
        if init == "ones":
            return jnp.ones(shape, self.dtype)
        if init == "normal":
            if scale is None:
                # fan-in scaling on the penultimate dim (lecun-normal-ish)
                fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
                scale = 1.0 / np.sqrt(max(fan_in, 1))
            return scale * jax.random.normal(k, shape, self.dtype)
        if init == "embed":
            return (scale or 1.0) * jax.random.normal(k, shape, self.dtype)
        if init == "uniform":
            lim = scale or 1.0 / np.sqrt(max(shape[-1], 1))
            return jax.random.uniform(k, shape, self.dtype, -lim, lim)
        raise ValueError(f"unknown init '{init}'")


def tree_pspecs(spec_tree: Any, shape_tree: Any, mesh: Mesh,
                rules: Mapping[str, MeshAxis]) -> Any:
    """Resolve a pytree of logical-axes tuples into PartitionSpecs."""
    def _one(axes, arr):
        shape = arr.shape if hasattr(arr, "shape") else arr
        return resolve_pspec(axes, shape, mesh, rules)
    return jax.tree.map(_one, spec_tree, shape_tree, is_leaf=is_axes)


def tree_shardings(spec_tree: Any, shape_tree: Any, mesh: Mesh,
                   rules: Mapping[str, MeshAxis]) -> Any:
    return jax.tree.map(lambda p: NamedSharding(mesh, p),
                        tree_pspecs(spec_tree, shape_tree, mesh, rules),
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Activation sharding constraints (no-op outside a logical_sharding context)
# ---------------------------------------------------------------------------
_CTX = threading.local()


@contextlib.contextmanager
def logical_sharding(mesh: Mesh, rules: Mapping[str, MeshAxis]):
    """Enable ``constrain`` inside model code for this mesh/rule table."""
    prev = getattr(_CTX, "val", None)
    _CTX.val = (mesh, rules)
    try:
        yield
    finally:
        _CTX.val = prev


@contextlib.contextmanager
def suspend_logical_sharding():
    """Disable ``constrain`` (used inside shard_map bodies, where mesh axes
    are manual and with_sharding_constraint is disallowed)."""
    prev = getattr(_CTX, "val", None)
    _CTX.val = None
    try:
        yield
    finally:
        _CTX.val = prev


def constrain(x: jax.Array, axes: Axes) -> jax.Array:
    """``with_sharding_constraint`` by logical axes; identity if no context."""
    ctx = getattr(_CTX, "val", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = resolve_pspec(axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
