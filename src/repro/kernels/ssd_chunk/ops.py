"""jit'd wrapper: layout adaptation from ssm.ssd_chunked's (B,nc,...)
tensors to the kernel's flattened (B*nc, H, ...) grid."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.ssd_chunk.kernel import ssd_chunk_pallas


@jax.jit
def ssd_chunk_fused(Cc, Bc, xdt, dA_cs):
    """Cc/Bc (B,nc,Q,H,N), xdt (B,nc,Q,H,P), dA_cs (B,nc,H,Q) ->
    (y_diag (B,nc,Q,H,P), states (B,nc,H,P,N))."""
    Bsz, nc, Q, H, N = Cc.shape
    P = xdt.shape[-1]
    to_k = lambda t: t.transpose(0, 1, 3, 2, 4).reshape(Bsz * nc, H, Q, -1)
    y, st = ssd_chunk_pallas(
        to_k(Cc), to_k(Bc), to_k(xdt),
        dA_cs.reshape(Bsz * nc, H, Q),
        interpret=use_interpret())
    y = y.reshape(Bsz, nc, H, Q, P).transpose(0, 1, 3, 2, 4)
    st = st.reshape(Bsz, nc, H, P, N)
    return y, st
