"""SSD intra-chunk Pallas kernel (Mamba-2 dual form, steps 1-2).

TPU adaptation story (DESIGN.md §2/§6): the selective scan's chunk-local
work is exactly two MXU matmuls per (chunk, head) — scores = C B^T and
y = (scores * L) xdt — plus a rank-1-decay state reduction.  The kernel
fuses the segment-decay mask construction (cumsum differences ->
exp -> tril) with both matmuls in VMEM, so the (Q,Q) decay matrix L never
exists in HBM.

Grid: (B*nc, H) — one program per (sequence chunk, head).  VMEM per
program: Q*N*2 + Q*P + Q*Q fp32 ≈ 0.6 MB at (Q,N,P)=(256,128,64).
Q and N are multiples of 128 in the shipped configs (MXU-aligned);
P=64 rides the lane dimension.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(C_ref, B_ref, x_ref, dA_ref, y_ref, st_ref):
    C = C_ref[...][0, 0].astype(jnp.float32)                    # (Q,N)
    B = B_ref[...][0, 0].astype(jnp.float32)                    # (Q,N)
    x = x_ref[...][0, 0].astype(jnp.float32)                    # (Q,P)
    dA = dA_ref[...][0, 0].astype(jnp.float32)                  # (Q,)

    Q = C.shape[0]
    seg = dA[:, None] - dA[None, :]
    il = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jl = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(il >= jl, jnp.exp(seg), 0.0)                  # (Q,Q) in VMEM only
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))  # C B^T
    y = jax.lax.dot_general((scores * L).astype(x.dtype), x,
                            (((1,), (0,)), ((), ())))           # (Q,P)
    decay_out = jnp.exp(dA[-1] - dA)                            # (Q,)
    bw = B * decay_out[:, None]
    st = jax.lax.dot_general(x, bw, (((0,), (0,)), ((), ())))   # (P,N)
    y_ref[...] = y[None, None].astype(y_ref.dtype)
    st_ref[...] = st[None, None].astype(st_ref.dtype)


def ssd_chunk_pallas(C, B, xdt, dA_cs, *, interpret: bool = True):
    """C,B (BN, H, Q, N); xdt (BN, H, Q, P); dA_cs (BN, H, Q).

    BN = batch*chunks flattened.  Returns (y (BN,H,Q,P), state (BN,H,P,N)).
    """
    BN, H, Q, N = C.shape
    P = xdt.shape[-1]
    grid = (BN, H)
    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, N), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, Q, P), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, Q), lambda i, j: (i, j, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, 1, Q, P), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda i, j: (i, j, 0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((BN, H, Q, P), xdt.dtype),
            jax.ShapeDtypeStruct((BN, H, P, N), jnp.float32),
        ),
        interpret=interpret,
    )(C, B, xdt, dA_cs)
