"""Pure-jnp oracle for the SSD intra-chunk kernel (Mamba-2 dual form).

Per (batch, chunk, head): given the chunk's C (Q,N), B (Q,N), dt-weighted
inputs xdt (Q,P) and within-chunk cumulative log-decay dA_cs (Q,):

  L[i,j]   = exp(dA_cs[i] - dA_cs[j])  for i >= j else 0   (segment decay)
  y_diag   = ((C @ B^T) * L) @ xdt                          (Q,P)
  decay_out= exp(dA_cs[-1] - dA_cs)                         (Q,)
  state    = (B * decay_out[:,None] * ... )^T formulation:
  state    = einsum('qn,q,qp->pn', B, decay_out, xdt)       (P,N)

These are steps 1-2 of ssm.ssd_chunked; the inter-chunk recurrence and the
state->output term stay in JAX (tiny O(S/Q) scan).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunk_ref(C, B, xdt, dA_cs):
    """C,B (..., Q, N); xdt (..., Q, P); dA_cs (..., Q) ->
    (y_diag (..., Q, P), state (..., P, N)).  fp32 math."""
    C = C.astype(jnp.float32)
    B = B.astype(jnp.float32)
    xdt = xdt.astype(jnp.float32)
    dA_cs = dA_cs.astype(jnp.float32)
    Q = C.shape[-2]
    seg = dA_cs[..., :, None] - dA_cs[..., None, :]             # (...,Q,Q)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask, jnp.exp(seg), 0.0)
    scores = jnp.einsum("...qn,...kn->...qk", C, B)
    y = jnp.einsum("...qk,...kp->...qp", scores * L, xdt)
    decay_out = jnp.exp(dA_cs[..., -1:] - dA_cs)                # (...,Q)
    state = jnp.einsum("...qn,...q,...qp->...pn", B, decay_out, xdt)
    return y, state
