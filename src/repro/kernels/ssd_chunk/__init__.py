from repro.kernels.ssd_chunk.ops import ssd_chunk_fused  # noqa: F401
