"""jit'd wrapper for the fused LSTM cell (+ layout adapter from the
(D, 4H) packed layout used by core/temporal.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.lstm_cell.kernel import lstm_cell_pallas


@jax.jit
def lstm_cell_fused(x, h, c, wx, wh, b):
    """Fused LSTM cell.  wx (D,4,H), wh (H,4,H), b (4,H)."""
    return lstm_cell_pallas(x, h, c, wx, wh, b, interpret=use_interpret())


def pack_weights(wx_flat: jax.Array, wh_flat: jax.Array, b_flat: jax.Array):
    """(D,4H)/(H,4H)/(4H,) packed (i|f|g|o) -> kernel layout (D,4,H) etc."""
    D, H4 = wx_flat.shape
    H = H4 // 4
    wx = wx_flat.reshape(D, 4, H)
    wh = wh_flat.reshape(wh_flat.shape[0], 4, H)
    b = b_flat.reshape(4, H)
    return wx, wh, b
