"""Fused LSTM cell kernel: both gate matmuls + all four nonlinearities +
state update in one VMEM pass (the paper's temporal-block hot-spot).

Grid: (B/bt, H/ht).  Weight layout (D, 4, H) / (H, 4, H) so an output
H-tile slices the last axis only — the two dot_generals contract the full
D / H axes (which are <=~2k for Dom-ST; they hit the MXU as (bt, D) x
(D, 4*ht) matmuls), and the gate nonlinearities + state update fuse in
registers instead of materializing the (B, 4H) gate tensor in HBM.
Tiles: ht a multiple of 128 where H allows (lane alignment), bt 8.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lstm_kernel(x_ref, h_ref, c_ref, wx_ref, wh_ref, b_ref,
                 ho_ref, co_ref):
    x = x_ref[...].astype(jnp.float32)                          # (bt, D)
    h = h_ref[...].astype(jnp.float32)                          # (bt, H)
    c = c_ref[...].astype(jnp.float32)                          # (bt, ht)
    wx = wx_ref[...].astype(jnp.float32)                        # (D, 4, ht)
    wh = wh_ref[...].astype(jnp.float32)                        # (H, 4, ht)
    b = b_ref[...].astype(jnp.float32)                          # (4, ht)

    D = x.shape[1]
    Hfull = h.shape[1]
    ht = c.shape[1]
    gates = (jax.lax.dot_general(x, wx.reshape(D, 4 * ht),
                                 (((1,), (0,)), ((), ())))
             + jax.lax.dot_general(h, wh.reshape(Hfull, 4 * ht),
                                   (((1,), (0,)), ((), ()))))
    gates = gates.reshape(x.shape[0], 4, ht) + b[None]
    i, f, g, o = gates[:, 0], gates[:, 1], gates[:, 2], gates[:, 3]
    c_new = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    ho_ref[...] = h_new.astype(ho_ref.dtype)
    co_ref[...] = c_new.astype(co_ref.dtype)


def lstm_cell_pallas(x, h, c, wx, wh, b, *, block_b: int = 8,
                     block_h: int = 128, interpret: bool = True):
    B, D = x.shape
    H = h.shape[1]
    bt = min(block_b, B)
    ht = min(block_h, H)
    grid = (pl.cdiv(B, bt), pl.cdiv(H, ht))
    out_shape = (jax.ShapeDtypeStruct((B, H), h.dtype),
                 jax.ShapeDtypeStruct((B, H), c.dtype))
    return pl.pallas_call(
        _lstm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, D), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, H), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, ht), lambda i, j: (i, j)),
            pl.BlockSpec((D, 4, ht), lambda i, j: (0, 0, j)),
            pl.BlockSpec((H, 4, ht), lambda i, j: (0, 0, j)),
            pl.BlockSpec((4, ht), lambda i, j: (0, j)),
        ],
        out_specs=(pl.BlockSpec((bt, ht), lambda i, j: (i, j)),
                   pl.BlockSpec((bt, ht), lambda i, j: (i, j))),
        out_shape=out_shape,
        interpret=interpret,
    )(x, h, c, wx, wh, b)
