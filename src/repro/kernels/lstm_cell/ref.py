"""Pure-jnp oracle for the fused LSTM cell kernel.

Weight layout: wx (D, 4, H), wh (H, 4, H), b (4, H) with gate order
(i, f, g, o); forget bias +1 matches core/temporal.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lstm_cell_ref(x, h, c, wx, wh, b):
    """x (B,D), h/c (B,H) -> (h', c'), all math in fp32."""
    xf = x.astype(jnp.float32)
    hf = h.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    gates = (jnp.einsum("bd,dgh->bgh", xf, wx.astype(jnp.float32))
             + jnp.einsum("bk,kgh->bgh", hf, wh.astype(jnp.float32))
             + b.astype(jnp.float32))
    i, f, g, o = gates[:, 0], gates[:, 1], gates[:, 2], gates[:, 3]
    c_new = jax.nn.sigmoid(f + 1.0) * cf + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new.astype(h.dtype), c_new.astype(c.dtype)
