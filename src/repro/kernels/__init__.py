"""Pallas TPU kernels for the paper's compute hot-spots (DESIGN.md §6).

Each kernel package has:
  kernel.py — pl.pallas_call body with explicit BlockSpec VMEM tiling
  ops.py    — jit'd public wrapper (composition, long-sequence chunking)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels: pixcon (fused contribution gating), conv1d (causal depthwise),
lstm_cell (fused gates), ssd_chunk (Mamba-2 intra-chunk dual form),
local_attn (sliding-window flash attention), paged_attn (fused
page-table lookup + gather + online-softmax attend for paged serving).

Interpret-vs-native lowering and the paged-attention dispatch flag are
decided lazily per trace by ``repro.kernels.common`` (use_interpret /
use_paged_attn_kernel) — on this CPU container kernels run with
interpret=True; on TPU the same pallas_call lowers natively.
"""
