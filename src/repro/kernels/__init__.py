"""Pallas TPU kernels for the paper's compute hot-spots (DESIGN.md §6).

Each kernel package has:
  kernel.py — pl.pallas_call body with explicit BlockSpec VMEM tiling
  ops.py    — jit'd public wrapper (composition, long-sequence chunking)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels: pixcon (fused contribution gating), conv1d (causal depthwise),
lstm_cell (fused gates), ssd_chunk (Mamba-2 intra-chunk dual form),
local_attn (sliding-window flash attention).

On this CPU container kernels run with interpret=True; on TPU the same
pallas_call lowers natively.
"""
