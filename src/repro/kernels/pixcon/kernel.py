"""Fused Pix-Con gating kernel (pl.pallas_call + BlockSpec VMEM tiling).

Fuses: contribution-MLP -> sigmoid -> pixel normalization -> broadcast
multiply, in one VMEM pass over the (B-tile, T-tile) grid — the weight
tensor w (B,P) never round-trips to HBM (the paper's Pix-Con transforms
every input pixel, so on TPU the fusion saves one full read+write of x).

Grid: (B/bt, T/tt).  Blocks keep the full pixel axis P resident (the
normalization reduces over P); P and the MLP hidden dim are tiny (<=1k),
so the working set is bt*tt*P + bt*P*(F+H) floats — a few hundred KB,
well under VMEM.  The MLP is recomputed per T-tile; it is O(P*F*H) versus
the O(tt*P) gating it fuses into, i.e. negligible.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pixcon_kernel(x_ref, feats_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref,
                   *, temperature: float, normalize: bool):
    feats = feats_ref[...].astype(jnp.float32)                  # (bt,P,F)
    w1 = w1_ref[...].astype(jnp.float32)                        # (F,H)
    b1 = b1_ref[...].astype(jnp.float32)                        # (H,)
    w2 = w2_ref[...].astype(jnp.float32)                        # (H,)
    b2 = b2_ref[...].astype(jnp.float32)                        # (1,)

    h = jnp.tanh(jax.lax.dot_general(
        feats, w1, (((2,), (0,)), ((), ()))) + b1)              # (bt,P,H)
    s = jax.lax.dot_general(h, w2, (((2,), (0,)), ((), ()))) + b2[0]  # (bt,P)
    w = jax.nn.sigmoid(s / temperature)
    if normalize:
        denom = jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-6)
        w = w * (w.shape[-1] / denom)

    x = x_ref[...].astype(jnp.float32)                          # (bt,tt,P)
    o_ref[...] = (x * w[:, None, :]).astype(o_ref.dtype)


def pixcon_gate_pallas(x: jax.Array, feats: jax.Array, w1: jax.Array,
                       b1: jax.Array, w2: jax.Array, b2: jax.Array, *,
                       temperature: float = 1.0, normalize: bool = True,
                       block_b: int = 8, block_t: int = 128,
                       interpret: bool = True) -> jax.Array:
    B, T, P = x.shape
    F, H = w1.shape
    bt = min(block_b, B)
    tt = min(block_t, T)
    grid = (pl.cdiv(B, bt), pl.cdiv(T, tt))
    kern = functools.partial(_pixcon_kernel, temperature=temperature,
                             normalize=normalize)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, tt, P), lambda i, j: (i, j, 0)),
            pl.BlockSpec((bt, P, F), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((F, H), lambda i, j: (0, 0)),
            pl.BlockSpec((H,), lambda i, j: (0,)),
            pl.BlockSpec((H,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, tt, P), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, P), x.dtype),
        interpret=interpret,
    )(x, feats, w1, b1, w2, b2)
