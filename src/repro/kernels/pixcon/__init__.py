from repro.kernels.pixcon.ops import pixcon_gate  # noqa: F401
