"""jit'd public wrapper for the fused Pix-Con gating kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.pixcon.kernel import pixcon_gate_pallas


@functools.partial(jax.jit, static_argnames=("temperature", "normalize"))
def pixcon_gate(x: jax.Array, feats: jax.Array, w1: jax.Array, b1: jax.Array,
                w2: jax.Array, b2: jax.Array, *, temperature: float = 1.0,
                normalize: bool = True) -> jax.Array:
    """Fused Pix-Con gating.  x (B,T,P), feats (B,P,F) -> gated x."""
    w2v = w2.reshape(-1)
    b2v = b2.reshape(1)
    return pixcon_gate_pallas(x, feats, w1, b1, w2v, b2v,
                              temperature=temperature, normalize=normalize,
                              interpret=use_interpret())
