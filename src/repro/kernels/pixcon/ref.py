"""Pure-jnp oracle for the fused Pix-Con gating kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pixcon_gate_ref(x: jax.Array, feats: jax.Array, w1: jax.Array,
                    b1: jax.Array, w2: jax.Array, b2: jax.Array,
                    *, temperature: float = 1.0,
                    normalize: bool = True) -> jax.Array:
    """x (B,T,P), feats (B,P,F); MLP weights w1 (F,H), b1 (H,), w2 (H,), b2 ().

    score = tanh(feats @ w1 + b1) @ w2 + b2
    w     = sigmoid(score / temperature)     [optionally sum-normalized * P]
    out   = x * w[:, None, :]
    """
    h = jnp.tanh(jnp.einsum("bpf,fh->bph", feats.astype(jnp.float32),
                            w1.astype(jnp.float32)) + b1.astype(jnp.float32))
    s = jnp.einsum("bph,h->bp", h, w2.astype(jnp.float32)) + b2.astype(jnp.float32)
    w = jax.nn.sigmoid(s / temperature)
    if normalize:
        w = w * (w.shape[-1] / jnp.maximum(jnp.sum(w, -1, keepdims=True), 1e-6))
    return (x.astype(jnp.float32) * w[:, None, :]).astype(x.dtype)
