"""Pure-jnp oracle for the sliding-window flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def local_attention_ref(q, k, v, *, window: int, causal: bool = True):
    """q (B,S,Hq,D), k/v (B,S,Hkv,D); causal band 0 <= q_pos-k_pos < window."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    kq = jnp.repeat(k, G, axis=2)
    vq = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kq.astype(jnp.float32)) / np.sqrt(D)
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = (qi - ki < window)
    if causal:
        mask &= ki <= qi
    else:
        mask &= (ki - qi < window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vq.astype(jnp.float32))
    return out.astype(q.dtype)
