from repro.kernels.local_attn.ops import local_attention_fused  # noqa: F401
