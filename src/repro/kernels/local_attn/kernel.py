"""Sliding-window flash attention Pallas kernel.

Grid: (B, Hq, S/bq, W/bq + 1) — the innermost axis walks the KV blocks in
a q-block's window (both directions for causal=False, so 2*W/bq + 1
steps); the output block index repeats across it, so the
online-softmax state (m, l, acc) lives in VMEM scratch and the output is
committed on the last window step.  FLOPs are O(S * (W + bq)) — the
sub-quadratic path gemma2/recurrentgemma need at long context — and live
VMEM is one (bq, bq) score tile + the (bq, D) accumulator.

GQA is handled in the index maps (kv head = q head // G), so no repeated
K/V ever materializes.  Positions are derived from grid indices; KV block
reads below position 0 are clamped to block 0 and masked.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, nwin: int, back: int, window: int, causal: bool,
            seq_len: int):
    i = pl.program_id(2)                 # q block
    j = pl.program_id(3)                 # window step

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...][0, :, 0, :].astype(jnp.float32)              # (bq, D)
    k = k_ref[...][0, :, 0, :].astype(jnp.float32)              # (bq, D)
    v = v_ref[...][0, :, 0, :].astype(jnp.float32)

    D = q.shape[-1]
    kb = i - back + j                                           # true kv block
    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)
    k_pos = kb * bq + jax.lax.broadcasted_iota(jnp.int32, (1, bq), 1)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) / np.sqrt(D)
    delta = q_pos - k_pos
    # k_pos < seq_len: the ops wrapper pads S up to a q-block multiple, and
    # the padded (zero) keys land INSIDE a tail query's window on the
    # non-causal branch (ahead of the query, within `window`) — the causal
    # branch happened to exclude them via delta >= 0, the non-causal branch
    # attended to them.
    mask = (k_pos >= 0) & (k_pos < seq_len) & (delta < window)
    if causal:
        mask = mask & (delta >= 0)
    else:
        mask = mask & (-delta < window)
    s = jnp.where(mask, s, -1e30)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + \
        jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(j == nwin - 1)
    def _commit():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[...] = out[None, :, None, :].astype(o_ref.dtype)


def local_attention_pallas(q, k, v, *, window: int, causal: bool = True,
                           block_q: int = 128, seq_len: int | None = None,
                           interpret: bool = True):
    """q (B,S,Hq,D), k/v (B,S,Hkv,D) -> (B,S,Hq,D).

    ``seq_len`` is the true (pre-padding) sequence length; keys at or past
    it are masked.  Defaults to S (no padding).
    """
    B, S, Hq, D = q.shape
    if seq_len is None:
        seq_len = S
    Hkv = k.shape[2]
    G = Hq // Hkv
    bq = min(block_q, S)
    assert S % bq == 0, (S, bq)
    win_blocks = (window + bq - 1) // bq
    # Backward blocks cover q_pos - k_pos < window; the non-causal branch
    # also attends FORWARD (k_pos - q_pos < window), so its walk extends
    # the same number of blocks past the query block — the old walk
    # stopped at block i and silently dropped forward keys in block i+1+.
    back = win_blocks
    fwd = 0 if causal else win_blocks
    nwin = back + fwd + 1
    nqb = S // bq
    grid = (B, Hq, nqb, nwin)

    def k_idx(b, h, i, j):
        kb = i - back + j
        return (b, jnp.clip(kb, 0, nqb - 1), h // G, 0)

    kern = functools.partial(_kernel, bq=bq, nwin=nwin, back=back,
                             window=window, causal=causal, seq_len=seq_len)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bq, 1, D), k_idx),
            pl.BlockSpec((1, bq, 1, D), k_idx),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, Hq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denom
            pltpu.VMEM((bq, D), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
