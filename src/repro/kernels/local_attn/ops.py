"""jit'd wrapper: pads S to a q-block multiple around the kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.local_attn.kernel import local_attention_pallas


@functools.partial(jax.jit, static_argnames=("window", "causal", "block_q"))
def local_attention_fused(q, k, v, *, window: int, causal: bool = True,
                          block_q: int = 128):
    B, S, Hq, D = q.shape
    bq = min(block_q, S)
    pad = (-S) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out = local_attention_pallas(q, k, v, window=window, causal=causal,
                                 block_q=bq, seq_len=S,
                                 interpret=use_interpret())
    return out[:, :S]
