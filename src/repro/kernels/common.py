"""Shared kernel-harness policy: interpret-mode and dispatch decisions.

Every ``ops.py`` wrapper used to snapshot ``jax.default_backend()`` into a
module-level ``INTERPRET`` constant at import time — which froze the
backend before any later platform selection and read
``REPRO_PALLAS_INTERPRET`` exactly once.  Both decisions live here now and
are evaluated LAZILY (at trace time, inside the jitted wrappers), so they
see the backend and environment of the call that actually lowers the
kernel.

Contract:

* ``use_interpret()`` — True means ``pl.pallas_call(..., interpret=True)``
  (the Pallas interpreter, any backend); False means native Mosaic
  lowering.  ``REPRO_PALLAS_INTERPRET=1`` forces the interpreter even on
  TPU (debugging); ``REPRO_PALLAS_INTERPRET=0`` forces native lowering
  even off-TPU (lowering tests only — it will fail at compile time on
  backends without Mosaic).  Unset: interpret everywhere but TPU.
* ``use_paged_attn_kernel()`` — whether the paged-attention serve paths
  in ``models/attention.py`` dispatch to the fused Pallas kernel triple
  (``kernels/paged_attn``) instead of the lax ``gather_pages`` +
  ``attend_cached`` fallback.  ``REPRO_PAGED_ATTN=1|fused`` forces the
  kernel (interpret mode included — how CPU CI smokes the path);
  ``REPRO_PAGED_ATTN=0|lax`` forces the fallback; unset/``auto``: the
  kernel on TPU (where it is the fast path), the fallback elsewhere
  (interpret mode is a correctness tool, not a fast path).

Both are read at TRACE time: a jitted wrapper bakes the decision into its
compiled executable, so flipping the environment variable affects new
traces (new shapes, new engine instances), not already-compiled calls.
"""
from __future__ import annotations

import os

import jax


def use_interpret() -> bool:
    """Run Pallas kernels under the interpreter?  (lazy, per-trace)."""
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env != "0"
    return jax.default_backend() != "tpu"


def use_paged_attn_kernel() -> bool:
    """Dispatch paged attention to the fused Pallas kernel?  (lazy)."""
    env = os.environ.get("REPRO_PAGED_ATTN", "auto").lower()
    if env in ("1", "fused", "on"):
        return True
    if env in ("0", "lax", "off"):
        return False
    return jax.default_backend() == "tpu"
