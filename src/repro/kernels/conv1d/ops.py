"""jit'd wrapper: VMEM-sized sequence chunking around the conv kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.conv1d.kernel import causal_conv1d_pallas

# keep a block's (bt, S+K-1, ct) slice well under VMEM: 8*2048*128*4 ≈ 8 MB
_MAX_SEQ_PER_CALL = 2048


@functools.partial(jax.jit, static_argnames=("activation",))
def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array, *,
                  activation: str = "none") -> jax.Array:
    """Causal depthwise conv1d.  x (B,S,C), w (K,C), b (C,)."""
    B, S, C = x.shape
    K = w.shape[0]
    if S <= _MAX_SEQ_PER_CALL:
        return causal_conv1d_pallas(x, w, b, activation=activation,
                                    interpret=use_interpret())
    # chunk over S, carrying the K-1 tail (same recurrence as decode)
    n = S // _MAX_SEQ_PER_CALL
    rem = S - n * _MAX_SEQ_PER_CALL
    outs = []
    tail = jnp.zeros((B, K - 1, C), x.dtype)
    for i in range(n + (1 if rem else 0)):
        lo = i * _MAX_SEQ_PER_CALL
        hi = min(S, lo + _MAX_SEQ_PER_CALL)
        xc = jax.lax.dynamic_slice_in_dim(x, lo, hi - lo, axis=1)
        xc_ext = jnp.concatenate([tail, xc], axis=1)
        yc = causal_conv1d_pallas(xc_ext, w, b, activation=activation,
                                  interpret=use_interpret())[:, K - 1:]
        outs.append(yc)
        tail = xc[:, -(K - 1):] if K > 1 else tail
    return jnp.concatenate(outs, axis=1)
