"""Causal depthwise conv1d kernel (Mamba-2 conv, Dom-ST spatial head,
RG-LRU temporal conv).

Grid: (B/bt, C/ct).  Each block holds its (bt, S + K - 1, ct) slice in
VMEM — the caller front-pads x by K-1 zeros so every block's window reads
are in-bounds and *aligned* (no halo exchange between blocks; the K-1
overlap is re-read from HBM, which for K<=4 is <0.1% extra traffic).
The ops.py wrapper chunks long sequences so the S-extent of a block stays
VMEM-sized, carrying the K-1 tail between chunks exactly like the decode
path does.

Channel tiles are multiples of 128 where C allows (lane alignment); the
kernel is memory-bound (K FMA per element), so the win on TPU is purely
the fusion of pad + K shifted multiplies + bias + SiLU into one pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(xp_ref, w_ref, b_ref, o_ref, *, K: int, S: int,
                 activation: str):
    xp = xp_ref[...].astype(jnp.float32)                        # (bt, S+K-1, ct)
    w = w_ref[...].astype(jnp.float32)                          # (K, ct)
    b = b_ref[...].astype(jnp.float32)                          # (ct,)
    acc = jnp.zeros((xp.shape[0], S, xp.shape[2]), jnp.float32)
    for k in range(K):
        acc = acc + xp[:, k:k + S, :] * w[k][None, None, :]
    acc = acc + b[None, None, :]
    if activation == "silu":
        acc = acc * jax.nn.sigmoid(acc)
    o_ref[...] = acc.astype(o_ref.dtype)


def causal_conv1d_pallas(x: jax.Array, w: jax.Array, b: jax.Array, *,
                         activation: str = "none",
                         block_b: int = 8, block_c: int = 128,
                         interpret: bool = True) -> jax.Array:
    B, S, C = x.shape
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))               # causal pad
    bt = min(block_b, B)
    ct = min(block_c, C)
    grid = (pl.cdiv(B, bt), pl.cdiv(C, ct))
    kern = functools.partial(_conv_kernel, K=K, S=S, activation=activation)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, S + K - 1, ct), lambda i, j: (i, 0, j)),
            pl.BlockSpec((K, ct), lambda i, j: (0, j)),
            pl.BlockSpec((ct,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bt, S, ct), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((B, S, C), x.dtype),
        interpret=interpret,
    )(xp, w, b)
