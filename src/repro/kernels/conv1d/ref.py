"""Pure-jnp oracle for the causal depthwise conv1d kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def causal_conv1d_ref(x: jax.Array, w: jax.Array, b: jax.Array, *,
                      activation: str = "none") -> jax.Array:
    """x (B,S,C), w (K,C) depthwise, b (C,) -> (B,S,C), causal padding.

    y[t] = b + sum_k w[k] * x[t - (K-1) + k]    (x[<0] == 0)
    """
    K = w.shape[0]
    xf = x.astype(jnp.float32)
    xp = jnp.pad(xf, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i].astype(jnp.float32)
              for i in range(K))
    out = out + b.astype(jnp.float32)
    if activation == "silu":
        out = jax.nn.silu(out)
    elif activation != "none":
        raise ValueError(activation)
    return out.astype(x.dtype)
