"""Pure-jnp oracle for the fused paged-attention kernel.

Gathers the slot's pages exactly like ``models.attention.gather_pages``
(unassigned page -> pos -1, k/v 0), runs a full masked softmax, and
zeroes rows with no attendable entry — the kernel's l=0 semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def paged_attention_ref(q, k_pool, v_pool, pos_pool, page_rows, qpos, *,
                        window: int = 0, softcap: float = 0.0):
    """q (B,T,Hkv,G,D); pools (P,ps,Hkv,D)/(P,ps); page_rows (B,n);
    qpos (B,T) -> (B,T,Hkv,G,D)."""
    B, T, Hkv, G, D = q.shape
    P, ps = pos_pool.shape
    n = page_rows.shape[1]
    safe = jnp.where(page_rows >= 0, page_rows, P)
    k = jnp.take(k_pool, safe, axis=0, mode="fill",
                 fill_value=0).reshape(B, n * ps, Hkv, D)
    v = jnp.take(v_pool, safe, axis=0, mode="fill",
                 fill_value=0).reshape(B, n * ps, Hkv, D)
    kp = jnp.take(pos_pool, safe, axis=0, mode="fill",
                  fill_value=-1).reshape(B, n * ps)

    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(D)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    kpb = kp[:, None, None, None, :]                            # (B,1,1,1,L)
    pq = qpos[:, None, None, :, None]                           # (B,1,1,T,1)
    mask = (kpb >= 0) & (kpb <= pq)
    if window:
        mask = mask & (pq - kpb < window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)                                 # all-masked row -> 0
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).astype(q.dtype)
