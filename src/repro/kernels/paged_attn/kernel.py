"""Fused paged-attention Pallas kernel.

Fuses the three steps the lax serving path does separately — per-slot
page-table lookup, KV page gather, online-softmax attend — into one
kernel, so no (B, n*ps, Hkv, D) gathered copy of the cache ever
materializes.  One grid covers the three serve shapes: single-token
decode (T=1), speculative verify (T=k+1), and chunked prefill (T=chunk).

Grid: (B, Hkv, n) — the innermost axis walks a slot's page row.  The
page table (B, n) rides in scalar-prefetch memory (SMEM) so the K/V/pos
BlockSpec index maps can translate logical page j of slot b into the
physical pool page ``tab[b, j]`` before the block is fetched — this is
the "lookup fused into the gather" half; unassigned entries (-1) clamp
to page 0 and are masked in-kernel.  The online-softmax state lives in
f32 VMEM scratch keyed by flattened (T*G) query rows; the output block
index repeats across the page walk and is committed on the last page.

Masking is pure position metadata, identical to the lax
``attend_cached`` path: an entry is attendable iff its page is assigned,
its pos is not -1 (empty/recycled), pos <= q_pos (causality — this alone
makes speculative verify and chunked prefill correct), and optionally
q_pos - pos < window.  Rows with no attendable entry output 0 (their
softmax denominator never accumulates), matching ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tab_ref, q_ref, qp_ref, k_ref, v_ref, kp_ref, o_ref,
            m_ref, l_ref, acc_ref, *, T: int, G: int, n: int,
            window: int, softcap: float):
    b = pl.program_id(0)
    j = pl.program_id(2)                                        # page step

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...][0, :, 0, :, :]                               # (T, G, D)
    D = q.shape[-1]
    qf = q.reshape(T * G, D).astype(jnp.float32)
    k = k_ref[...][0, :, 0, :].astype(jnp.float32)              # (ps, D)
    v = v_ref[...][0, :, 0, :].astype(jnp.float32)
    kp = kp_ref[...][0]                                         # (ps,)
    qp = qp_ref[...][0]                                         # (T,)

    s = jax.lax.dot_general(qf, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s / np.sqrt(D)                                          # (T*G, ps)
    if q_ref.dtype != jnp.float32:
        # the lax path's score einsum runs in q.dtype before the f32 cast;
        # round through it so both paths see bit-identical scores
        s = s.astype(q_ref.dtype).astype(jnp.float32)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)

    valid_page = tab_ref[b, j] >= 0
    mask = valid_page & (kp[None, :] >= 0) & (kp[None, :] <= qp[:, None])
    if window:
        mask = mask & (qp[:, None] - kp[None, :] < window)      # (T, ps)
    mask = jnp.broadcast_to(mask[:, None, :], (T, G, kp.shape[0]))
    mask = mask.reshape(T * G, kp.shape[0])
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == n - 1)
    def _commit():
        l = l_ref[...]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
        out = jnp.where(l[:, None] > 0, out, 0.0)               # no attendable key
        o_ref[...] = out.reshape(T, G, D)[None, :, None].astype(o_ref.dtype)


def paged_attention_pallas(q, k_pool, v_pool, pos_pool, page_rows, qpos, *,
                           window: int = 0, softcap: float = 0.0,
                           interpret: bool = True):
    """q (B,T,Hkv,G,D); k/v pool (P,ps,Hkv,D); pos pool (P,ps);
    page_rows (B,n) physical page ids (-1 = unassigned); qpos (B,T)
    absolute query positions -> (B,T,Hkv,G,D)."""
    B, T, Hkv, G, D = q.shape
    ps = k_pool.shape[1]
    n = page_rows.shape[1]
    grid = (B, Hkv, n)

    def page_idx(b, h, j, tab):
        return (jnp.maximum(tab[b, j], 0), 0, h, 0)

    kern = functools.partial(_kernel, T=T, G=G, n=n, window=window,
                             softcap=softcap)
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, T, 1, G, D),
                             lambda b, h, j, tab: (b, 0, h, 0, 0)),
                pl.BlockSpec((1, T), lambda b, h, j, tab: (b, 0)),
                pl.BlockSpec((1, ps, 1, D), page_idx),
                pl.BlockSpec((1, ps, 1, D), page_idx),
                pl.BlockSpec((1, ps),
                             lambda b, h, j, tab: (jnp.maximum(tab[b, j], 0), 0)),
            ],
            out_specs=pl.BlockSpec((1, T, 1, G, D),
                                   lambda b, h, j, tab: (b, 0, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((T * G,), jnp.float32),      # running max
                pltpu.VMEM((T * G,), jnp.float32),      # running denom
                pltpu.VMEM((T * G, D), jnp.float32),    # output accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, T, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(page_rows, q, qpos, k_pool, v_pool, pos_pool)
