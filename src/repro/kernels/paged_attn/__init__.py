from repro.kernels.paged_attn.ops import paged_attention_fused  # noqa: F401
