"""jit'd wrapper: GQA head-grouping reshape around the fused kernel.

Unlike ``local_attn``'s wrapper there is NO sequence padding here — the
grid never tiles the query axis (T is a whole block) and the KV axis
tiles on the pool's native page size, so the padded-key masking bug
class audited in PR 6 cannot arise: every key the kernel sees is a real
pool entry, and emptiness is carried by pos = -1 / page id = -1 alone.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import use_interpret
from repro.kernels.paged_attn.kernel import paged_attention_pallas


@functools.partial(jax.jit, static_argnames=("window", "softcap"))
def paged_attention_fused(q, k_pool, v_pool, pos_pool, page_rows, qpos, *,
                          window: int = 0, softcap: float = 0.0):
    """q (B,T,Hq,D) vs the page pool -> (B,T,Hq,D), pre-out-projection.

    k/v_pool (P,ps,Hkv,D), pos_pool (P,ps) absolute positions (-1 empty),
    page_rows (B,n) per-slot physical page ids (-1 unassigned), qpos
    (B,T) absolute query positions.  ``window=0`` disables the sliding
    window, ``softcap=0`` disables logit softcapping.
    """
    B, T, Hq, D = q.shape
    Hkv = k_pool.shape[2]
    G = Hq // Hkv
    qr = q.reshape(B, T, Hkv, G, D)
    out = paged_attention_pallas(
        qr, k_pool, v_pool, pos_pool.astype(jnp.int32),
        page_rows.astype(jnp.int32), qpos.astype(jnp.int32),
        window=window, softcap=softcap, interpret=use_interpret())
    return out.reshape(B, T, Hq, D)
