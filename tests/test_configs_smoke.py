"""Deliverable (f): per-architecture smoke tests.

For each assigned architecture: instantiate the REDUCED variant of the same
family (2 layers, d_model<=512, <=4 experts) and run one forward/train step
on CPU asserting output shapes + no NaNs.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, TrainConfig, get_config, smoke_variant
from repro.data.tokens import synthetic_token_batch
from repro.models import transformer as tfm
from repro.optim import make_optimizer

SEQ = 16
BATCH = 2


def _batch(cfg):
    return {k: jnp.asarray(v)
            for k, v in synthetic_token_batch(cfg, BATCH, SEQ).items()}


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    spec = {
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "deepseek-moe-16b": (28, 2048, 16, 16, None, 102400),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, None, 151936),
        "mamba2-130m": (24, 768, None, None, 0, 50280),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
    }[arch]
    L, d, h, kv, ff, v = spec
    assert cfg.num_layers == L and cfg.d_model == d and cfg.vocab_size == v
    if h is not None:
        assert cfg.num_heads == h and cfg.num_kv_heads == kv
    if ff is not None and ff:
        assert cfg.d_ff == ff
    # family extras
    if arch == "deepseek-moe-16b":
        assert cfg.moe.num_experts == 64 and cfg.moe.top_k == 6
        assert cfg.moe.num_shared == 2 and cfg.moe.d_ff_expert == 1408
    if arch == "qwen3-moe-30b-a3b":
        assert cfg.moe.num_experts == 128 and cfg.moe.top_k == 8
    if arch == "mamba2-130m":
        assert cfg.ssm.state_dim == 128
    if arch == "qwen2-1.5b":
        assert cfg.qkv_bias
    if arch == "gemma2-2b":
        assert cfg.logit_softcap == 30.0 and cfg.window == 4096


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_shapes_no_nan(arch, key):
    cfg = smoke_variant(get_config(arch))
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = tfm.init(cfg, key)
    batch = _batch(cfg)
    x, aux = tfm.forward(params, cfg, batch)
    S = SEQ if cfg.family != "vlm" else SEQ  # vlm: patches + text == SEQ
    assert x.shape == (BATCH, S, cfg.d_model)
    assert not bool(jnp.any(jnp.isnan(x)))
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step(arch, key):
    cfg = smoke_variant(get_config(arch))
    tc = TrainConfig(learning_rate=1e-3, total_steps=10, warmup_steps=1)
    params = tfm.init(cfg, key)
    opt_init, opt_update = make_optimizer(tc)
    opt = opt_init(params)
    batch = _batch(cfg)

    @jax.jit
    def step(p, o, b):
        (loss, _), g = jax.value_and_grad(
            lambda q: tfm.lm_loss(q, cfg, b), has_aux=True)(p)
        p, o, m = opt_update(p, g, o)
        return p, o, loss

    p1, o1, loss = step(params, opt, batch)
    assert not bool(jnp.isnan(loss)) and float(loss) > 0
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)))
    assert delta > 0
