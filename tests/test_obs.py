"""Unified telemetry subsystem (repro/obs/): histogram quantile accuracy
vs numpy, dict-compat registry views (StatGroup/Series), Chrome trace-event
span schema and nesting, tracing-on/off stream parity across arch families,
span-derived TTFT vs the legacy per-request dict, and span coverage of the
serve window."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke_variant
from repro.models import transformer as T
from repro.obs import (
    Histogram, MetricRegistry, Tracer, derive_request_metrics, percentiles,
    span_coverage,
)
from repro.serve import InferenceEngine, Request, Scheduler, stream_digest

PROMPT, GEN = 8, 4

# one arch per family that supports decode: attention KV cache,
# sliding-window attention, and the recurrent (linear-RNN) cache path
PARITY_ARCHS = ["qwen2-1.5b", "gemma2-2b", "recurrentgemma-2b"]


def _requests(cfg, lens, gen=GEN, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, max_new=gen,
                    prompt=rng.integers(0, cfg.vocab_size, n).astype(np.int32))
            for i, n in enumerate(lens)]


def _serve(cfg, reqs, *, slots=2, sched_kw=None, **kw):
    eng = InferenceEngine(cfg, slots=slots, dtype=jnp.float32,
                          max_len=PROMPT + GEN, **kw)
    state = eng.init_state(T.init(cfg, jax.random.key(0)))
    sched = Scheduler(eng, state, **(sched_kw or {}))
    return sched.run(reqs), sched


# ---------------------------------------------------------------------------
# Histogram: exact-regime quantiles must MATCH numpy.percentile; after
# decimation they stay bounded; the registry views keep the dict protocol
# ---------------------------------------------------------------------------
def test_histogram_quantiles_match_numpy():
    hypothesis = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hypothesis.given(
        st.lists(st.floats(min_value=-1e9, max_value=1e9,
                           allow_nan=False, allow_infinity=False),
                 min_size=1, max_size=512),
        st.floats(min_value=0.0, max_value=100.0),
    )
    @hypothesis.settings(deadline=None, max_examples=200)
    def check(samples, q):
        h = Histogram()
        for s in samples:
            h.record(s)
        assert h.exact  # 512 <= exact_max: nothing decimated
        np.testing.assert_allclose(h.quantile(q), np.percentile(samples, q),
                                   rtol=1e-12, atol=1e-12)

    check()


def test_histogram_exact_regime_small():
    h = Histogram()
    for v in [5.0, 1.0, 3.0, 2.0, 4.0]:
        h.record(v)
    assert h.count == 5 and h.min == 1.0 and h.max == 5.0 and h.last == 4.0
    assert h.quantile(0) == 1.0 and h.quantile(100) == 5.0
    assert h.quantile(50) == 3.0
    assert h.mean == pytest.approx(3.0)


def test_histogram_overflow_regime_bounded_error():
    h = Histogram(exact_max=64)
    xs = np.linspace(0.0, 1.0, 1000)
    for v in xs:
        h.record(float(v))
    assert not h.exact and h.count == 1000
    assert h.min == 0.0 and h.max == 1.0
    for q in (10, 50, 90, 99):
        # decimation keeps every 2^k-th sample of a sorted buffer: the
        # quantile error is bounded by the local sample spacing
        assert abs(h.quantile(q) - np.percentile(xs, q)) < 0.05


def test_percentiles_helper():
    vals = list(range(1, 101))
    p = percentiles(vals, (50, 99))
    assert p["p50"] == pytest.approx(np.percentile(vals, 50))
    assert p["p99"] == pytest.approx(np.percentile(vals, 99))
    empty = percentiles([], (50, 99))
    assert np.isnan(empty["p50"]) and np.isnan(empty["p99"])


def test_statgroup_and_series_dict_compat():
    reg = MetricRegistry()
    g = reg.group("sched.run", {"a": 0.0, "b": 0.0})
    g["a"] += 2.0
    g["b"] = 7.0
    assert dict(g) == {"a": 2.0, "b": 7.0}
    assert set(g) == {"a", "b"} and len(g) == 2 and "a" in g
    g.reset()
    assert dict(g) == {"a": 0.0, "b": 0.0}
    # same prefix -> same live view (the scheduler's stats re-fetch)
    assert reg.group("sched.run", {"a": 0.0, "b": 0.0}) is g

    s = reg.series("serve.ttft_s")
    s[3] = 0.25
    assert s[3] == 0.25 and 3 in s and dict(s) == {3: 0.25}
    s.clear()
    assert len(s) == 0


# ---------------------------------------------------------------------------
# Tracer: Chrome trace-event schema, rid args, and non-overlap per track
# ---------------------------------------------------------------------------
def _trace_serve(arch="qwen2-1.5b", enabled=True):
    cfg = smoke_variant(get_config(arch))
    reqs = _requests(cfg, [PROMPT] * 4)
    tracer = Tracer(enabled=enabled)
    out, sched = _serve(cfg, reqs, sched_kw={"tracer": tracer})
    return out, sched, tracer


def test_span_schema_and_nesting():
    out, sched, tracer = _trace_serve()
    events = tracer.events()
    spans = [e for e in events if e["ph"] == "X"]
    assert spans, "tracing enabled but no spans recorded"
    names = {e["name"] for e in spans}
    for required in ("run", "iter", "admit", "prefill_insert",
                     "decode_step", "queued", "prefill", "decode"):
        assert required in names, (required, sorted(names))
    for e in spans:
        assert isinstance(e["name"], str) and e["name"]
        assert e["ts"] >= 0 and e["dur"] >= 0
        if e["tid"].startswith("rid"):
            assert e["args"]["rid"] == int(e["tid"][3:])
    # per-request lifecycle spans on each rid track are gapless and
    # sequential: sorted by ts, each span ends where the next begins
    for rid in range(4):
        track = sorted((e for e in spans if e["tid"] == f"rid{rid}"),
                       key=lambda e: e["ts"])
        assert [e["name"] for e in track][0] == "queued"
        for a, b in zip(track, track[1:]):
            assert a["ts"] + a["dur"] <= b["ts"] + 1  # <= 1 us rounding
    # the Chrome export maps string tids to ints and adds thread metadata
    doc = tracer.to_chrome()
    tids = {e["tid"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert all(isinstance(t, int) for t in tids)
    meta = {e["args"]["name"] for e in doc["traceEvents"] if e["ph"] == "M"
            and e["name"] == "thread_name"}
    assert "sched" in meta and "rid0" in meta


def test_derived_ttft_matches_legacy_dict():
    out, sched, tracer = _trace_serve()
    per = derive_request_metrics(tracer.events())
    assert set(per) == set(sched.ttft)
    for rid, legacy in sched.ttft.items():
        assert per[rid]["ttft_s"] == pytest.approx(legacy, abs=1e-3)
        assert per[rid]["tokens"] == len(out[rid])


def test_span_coverage_of_serve_window():
    out, sched, tracer = _trace_serve()
    assert span_coverage(tracer.events()) >= 0.95


# ---------------------------------------------------------------------------
# Observer purity: tracing on vs off must leave every stream bit-identical
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_tracing_parity(arch):
    out_on, _, tracer = _trace_serve(arch, enabled=True)
    out_off, _, off_tracer = _trace_serve(arch, enabled=False)
    assert not off_tracer.events()
    assert set(out_on) == set(out_off)
    for rid in out_on:
        assert out_on[rid] == out_off[rid], rid
    assert stream_digest(out_on) == stream_digest(out_off)
