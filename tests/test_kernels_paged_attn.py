"""Fused paged-attention kernel triple (kernels/paged_attn/).

Three rings of evidence, inside-out: the Pallas kernel vs its pure-jnp
oracle across GQA configs / ragged slot lengths / recycled pages; the
kernel vs the live lax fallback (``gather_pages`` + ``attend_masked``)
it replaces; and end-to-end greedy parity — an engine forced onto the
kernel path serves bit-identical streams to the lax-path engine across
plain decode, chunked prefill and speculative verify.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.kernels.common import use_interpret, use_paged_attn_kernel
from repro.kernels.paged_attn.ops import paged_attention_fused
from repro.kernels.paged_attn.ref import paged_attention_ref
from repro.models import transformer as T
from repro.models.attention import (
    PagedKVCache, attend_masked, gather_pages, paged_decode_attention,
    paged_multitok_attention,
)
from repro.serve import InferenceEngine, NgramDrafter, Request, Scheduler

PS = 4                                  # page size used throughout


def _pool_and_slots(rng, lens, *, Hkv, D, n=4, extra_pages=2,
                    recycled=(), dtype=jnp.float32):
    """Build a pool + per-slot page tables for ``lens[b]`` cached tokens.

    Physical page ids are handed out in shuffled order (tables are NOT
    the identity map); slots with fewer than ``n`` pages keep -1 tails.
    ``recycled`` lists (slot, logical_page) pairs whose pos entries are
    reset to -1 — a page reclaimed and reassigned mid-generation."""
    B = len(lens)
    need = [-(-l // PS) for l in lens]
    P = sum(need) + extra_pages
    perm = rng.permutation(P)
    k = jnp.asarray(rng.normal(0, 1, (P, PS, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (P, PS, Hkv, D)), dtype)
    pos = np.full((P, PS), -1, np.int32)
    rows = np.full((B, n), -1, np.int32)
    it = iter(perm)
    for b, l in enumerate(lens):
        for j in range(need[b]):
            p = int(next(it))
            rows[b, j] = p
            fill = min(PS, l - j * PS)
            pos[p, :fill] = np.arange(j * PS, j * PS + fill)
    for b, j in recycled:
        pos[rows[b, j]] = -1
    cache = PagedKVCache(k, v, jnp.asarray(pos))
    return cache, jnp.asarray(rows)


@pytest.mark.parametrize("Hq,Hkv", [(4, 2), (2, 2), (4, 1)])
@pytest.mark.parametrize("Tq", [1, 4, 7])
@pytest.mark.parametrize("window,softcap", [(0, 0.0), (6, 0.0), (0, 30.0),
                                            (6, 30.0)])
def test_fused_matches_ref(rng, Hq, Hkv, Tq, window, softcap):
    """Decode (Tq=1), verify (Tq=k+1) and prefill-chunk (Tq=chunk) shapes
    vs the oracle, over ragged slot lengths and shuffled page tables."""
    D, lens = 16, [9, 3, 14]
    cache, rows = _pool_and_slots(rng, lens, Hkv=Hkv, D=D)
    B = len(lens)
    G = Hq // Hkv
    qpos = jnp.asarray([[l - 1 + t for t in range(Tq)] for l in lens],
                       jnp.int32)
    q = jnp.asarray(rng.normal(0, 1, (B, Tq, Hq, D)), jnp.float32)
    got = paged_attention_fused(q, cache.k, cache.v, cache.pos, rows, qpos,
                                window=window, softcap=softcap)
    want = paged_attention_ref(
        q.reshape(B, Tq, Hkv, G, D), cache.k, cache.v, cache.pos, rows,
        qpos, window=window, softcap=softcap).reshape(B, Tq, Hq, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_fused_matches_lax_gather_path(rng):
    """The kernel vs the exact lax code it replaces — gather_pages +
    attend_masked — including recycled (pos=-1) pages and a window."""
    import types
    D, Hq, Hkv, Tq = 16, 4, 2, 4
    lens = [11, 6, 2, 9]
    cache, rows = _pool_and_slots(rng, lens, Hkv=Hkv, D=D,
                                  recycled=[(0, 1), (2, 0)])
    B = len(lens)
    qpos = jnp.asarray([[l - 1 + t for t in range(Tq)] for l in lens],
                       jnp.int32)
    q = jnp.asarray(rng.normal(0, 1, (B, Tq, Hq, D)), jnp.float32)
    for window, cap in [(None, 0.0), (6, 0.0), (None, 30.0)]:
        cfg = types.SimpleNamespace(attn_softcap=cap)
        k_all, v_all, kp = gather_pages(cache, rows)
        want = attend_masked(cfg, q, k_all, v_all, kp, qpos, window=window)
        got = paged_attention_fused(q, cache.k, cache.v, cache.pos, rows,
                                    qpos, window=window or 0, softcap=cap)
        # rows with NO attendable key (fully recycled slot 2 at early qpos)
        # are 0 in the kernel but uniform-softmax garbage in the lax path;
        # compare only rows the mask leaves live
        live = np.asarray((kp[:, None, :] >= 0)
                          & (kp[:, None, :] <= qpos[:, :, None])).any(-1)
        np.testing.assert_allclose(np.asarray(got)[live],
                                   np.asarray(want)[live], atol=2e-5)


def test_fused_bf16_pool_matches_ref(rng):
    cache, rows = _pool_and_slots(rng, [7, 5], Hkv=2, D=16,
                                  dtype=jnp.bfloat16)
    qpos = jnp.asarray([[6], [4]], jnp.int32)
    q = jnp.asarray(rng.normal(0, 1, (2, 1, 4, 16)), jnp.bfloat16)
    got = paged_attention_fused(q, cache.k, cache.v, cache.pos, rows, qpos)
    want = paged_attention_ref(q.reshape(2, 1, 2, 2, 16), cache.k, cache.v,
                               cache.pos, rows, qpos).reshape(2, 1, 4, 16)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=4e-2)


# ---------------------------------------------------------------------------
# Dispatch: the env flag routes the live paged paths through the kernel
# ---------------------------------------------------------------------------
def _attn_setup(rng, key, arch="qwen2-1.5b", **over):
    from repro.distributed.sharding import ParamFactory
    from repro.models.attention import attn_params, init_paged_kv_cache
    cfg = smoke_variant(get_config(arch)).replace(**over)
    params = attn_params(ParamFactory(key), cfg)
    B, n = 2, 3
    cache = init_paged_kv_cache(B * n, PS, cfg.num_kv_heads,
                                cfg.resolved_head_dim(), dtype=jnp.float32)
    rows = jnp.arange(B * n, dtype=jnp.int32).reshape(B, n)
    return cfg, params, cache, rows


@pytest.mark.parametrize("arch,over", [
    ("qwen2-1.5b", {}),
    ("gemma2-2b", {"window": 6}),       # windowed + softcapped GQA
])
def test_dispatch_parity_multitok_and_decode(rng, key, monkeypatch,
                                             arch, over):
    """paged_multitok_attention (the verify/prefill path) and
    paged_decode_attention produce allclose outputs and IDENTICAL caches
    under REPRO_PAGED_ATTN=1 vs =0."""
    cfg, params, cache, rows = _attn_setup(rng, key, arch, **over)
    B, Tq = rows.shape[0], 3
    window = cfg.window if arch == "gemma2-2b" else None
    x = jnp.asarray(rng.normal(0, 1, (B, Tq, cfg.d_model)), jnp.float32)
    xd = jnp.asarray(rng.normal(0, 1, (B, 1, cfg.d_model)), jnp.float32)
    pos0 = jnp.asarray([0, 2], jnp.int32)
    outs, caches = {}, {}
    for flag in ("0", "1"):
        monkeypatch.setenv("REPRO_PAGED_ATTN", flag)
        o_m, c = paged_multitok_attention(params, cfg, x, cache, rows, pos0,
                                          window=window)
        o_d, c = paged_decode_attention(params, cfg, xd, c, rows, pos0 + Tq,
                                        window=window)
        outs[flag] = (o_m, o_d)
        caches[flag] = c
    # post-projection outputs accumulate O(d_model) reassociation noise;
    # the bit-level claim is made on caches here and on greedy streams in
    # the e2e test below
    for a, b in zip(outs["0"], outs["1"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)
    for a, b in zip(caches["0"], caches["1"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# End-to-end greedy parity: kernel-path serving == lax-path serving
# ---------------------------------------------------------------------------
def _serve(cfg, lens, *, spec_k=0, drafter=None, prefill_chunk=0,
           seed=0, gen=4):
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i, max_new=gen,
                    prompt=rng.integers(0, cfg.vocab_size, n).astype(np.int32))
            for i, n in enumerate(lens)]
    eng = InferenceEngine(cfg, slots=2, dtype=jnp.float32, max_len=16,
                          paged=True, page_size=PS,
                          prefill_chunk=prefill_chunk)
    state = eng.init_state(T.init(cfg, jax.random.key(0)))
    sched = Scheduler(eng, state, spec_k=spec_k, drafter=drafter)
    return sched.run(reqs)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "gemma2-2b"])
def test_e2e_greedy_parity_kernel_vs_lax(monkeypatch, arch):
    """The acceptance bar: decode + chunked prefill + speculative verify
    served entirely through the fused kernel emit streams bit-identical
    to the lax fallback, on a plain-GQA and a windowed+softcapped arch."""
    cfg = smoke_variant(get_config(arch))
    lens = [8, 5, 7, 6]
    runs = {}
    for flag in ("0", "1"):
        monkeypatch.setenv("REPRO_PAGED_ATTN", flag)
        runs[flag] = (
            _serve(cfg, lens),
            _serve(cfg, lens, prefill_chunk=3),
            _serve(cfg, lens, spec_k=3, drafter=NgramDrafter()),
        )
    assert runs["1"] == runs["0"], arch


# ---------------------------------------------------------------------------
# The lazy-env contract of kernels.common
# ---------------------------------------------------------------------------
def test_use_interpret_reads_env_lazily(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    on_cpu = jax.default_backend() != "tpu"
    assert use_interpret() == on_cpu
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert use_interpret() is False     # flipped AFTER import: must be seen
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert use_interpret() is True


def test_use_paged_attn_kernel_flag(monkeypatch):
    for val, want in [("1", True), ("fused", True), ("on", True),
                      ("0", False), ("lax", False), ("off", False)]:
        monkeypatch.setenv("REPRO_PAGED_ATTN", val)
        assert use_paged_attn_kernel() is want, val
    monkeypatch.delenv("REPRO_PAGED_ATTN", raising=False)
    assert use_paged_attn_kernel() == (jax.default_backend() == "tpu")
