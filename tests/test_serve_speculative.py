"""Speculative decoding over the paged KV pool (serve/speculative/).

The load-bearing property is LOSSLESSNESS: the serve path is greedy
end to end, so every token a speculative run emits must be bit-identical
to the ``spec_k == 0`` baseline — whatever the drafter proposes, however
many drafts are accepted, across attention-only, local/global and
recurrent-hybrid architectures, under ragged batches and slot reuse, on
one device and on the 8-device mesh.  A scripted drafter walks every
acceptance count 0..K so the rollback paths (positional shadowing of
rejected KV writes, per-step recurrent/SSM snapshot selection) are each
exercised deterministically; the self-draft ModelDrafter pins FULL
acceptance, which doubles as an exactness proof for the draft model's
catch-up/discard sync discipline.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import transformer as T
from repro.serve import (
    InferenceEngine, ModelDrafter, NgramDrafter, Request, Scheduler,
)

PROMPT, GEN, SPEC_K = 8, 6, 3
LENS = [8, 5, 7, 6]                     # ragged; slots=2 forces slot reuse


def _ample_moe(cfg):
    import dataclasses
    if cfg.moe is not None:
        return cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                   capacity_factor=8.0))
    return cfg


def _requests(cfg, lens=LENS, gen=GEN, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i, n in enumerate(lens):
        extras = {}
        if cfg.family == "vlm":
            extras["patches"] = rng.normal(
                0, 1, (cfg.num_patches, cfg.frontend_dim)).astype(np.float32)
        reqs.append(Request(
            rid=i, max_new=gen, extras=extras,
            prompt=rng.integers(0, cfg.vocab_size, n).astype(np.int32)))
    return reqs


def _serve(cfg, reqs, *, slots=2, spec_k=0, drafter=None, eos=None,
           mesh=None, max_len=16, **kw):
    eng = InferenceEngine(cfg, slots=slots, mesh=mesh, dtype=jnp.float32,
                          max_len=max_len, paged=True, page_size=4, **kw)
    state = eng.init_state(T.init(cfg, jax.random.key(0)))
    sched = Scheduler(eng, state, eos_id=eos, spec_k=spec_k,
                      drafter=drafter)
    return sched.run(reqs), sched


class ScriptedDrafter:
    """Proposes the known-correct greedy continuation for the first ``j``
    tokens of every draft, then a deliberately wrong token — so each
    verify round accepts exactly min(j, k) drafts and every acceptance
    count (full reject .. full accept) is hit deterministically."""

    def __init__(self, truth, vocab, j):
        self.truth = truth              # {prompt bytes: baseline tokens}
        self.vocab, self.j = vocab, j

    def propose(self, wants):
        out = {}
        for slot, (ctx, k) in wants.items():
            ctx = np.asarray(ctx, np.int32)
            for pb, cont in self.truth.items():
                p = np.frombuffer(pb, np.int32)
                if len(ctx) >= len(p) and (ctx[:len(p)] == p).all():
                    n_gen = len(ctx) - len(p)
                    good = list(cont[n_gen:n_gen + min(self.j, k)])
                    if len(good) < k:
                        nxt = cont[n_gen + len(good)] \
                            if n_gen + len(good) < len(cont) else 0
                        good.append((nxt + 1) % self.vocab)  # forced miss
                    out[slot] = np.asarray(good[:k], np.int32)
                    break
        return out

    def release(self, slot):
        pass


def _truth(cfg, ref):
    return {np.asarray(r.prompt, np.int32).tobytes(): ref[r.rid]
            for r in _requests(cfg)}


# ---------------------------------------------------------------------------
# Lossless greedy parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["olmo-1b", "qwen2-1.5b", "gemma2-2b",
                                  "recurrentgemma-2b", "mamba2-130m",
                                  "deepseek-moe-16b"])
def test_spec_ngram_parity(arch):
    """Ngram-drafted speculation under ragged batches and slot reuse emits
    the exact spec_k=0 streams (acceptance may be anything, including 0).
    The MoE arch runs with ample routing capacity, like every cross-path
    parity test (capacity drops are pass-shape-dependent by design)."""
    cfg = _ample_moe(smoke_variant(get_config(arch)))
    ref, _ = _serve(cfg, _requests(cfg))
    got, sched = _serve(cfg, _requests(cfg), spec_k=SPEC_K,
                        drafter=NgramDrafter())
    assert got == ref, arch
    assert sched.stats["spec_accepted"] <= sched.stats["spec_proposed"]


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "recurrentgemma-2b",
                                  "mamba2-130m"])
@pytest.mark.parametrize("j", [0, 1, 2, 3])
def test_spec_every_acceptance_count(arch, j):
    """Each rollback depth is exercised deterministically: j correct
    drafts then a forced miss -> rejected KV writes must be shadowed and
    recurrent/SSM state must roll back to the j-th snapshot."""
    cfg = smoke_variant(get_config(arch))
    ref, _ = _serve(cfg, _requests(cfg))
    got, sched = _serve(cfg, _requests(cfg), spec_k=SPEC_K,
                        drafter=ScriptedDrafter(_truth(cfg, ref),
                                                cfg.vocab_size, j))
    assert got == ref, (arch, j)
    if j > 0:
        assert sched.stats["spec_accepted"] > 0
    if j == 0:
        assert sched.stats["spec_accepted"] == 0
    # speculation must shorten the serve loop once drafts are accepted
    if j == SPEC_K:
        assert sched.stats["spec_accepted"] == sched.stats["spec_proposed"]


@pytest.mark.parametrize("arch", ["olmo-1b", "recurrentgemma-2b"])
def test_spec_model_drafter_self_draft_full_acceptance(arch):
    """A draft model with the target's own params proposes the target's
    own greedy continuation: every draft must be accepted.  Full
    acceptance is only reachable if the drafter's committed state is
    EXACTLY in sync (catch-up chunks + discarded speculative rollouts),
    so this doubles as the drafter-side correctness proof — including
    recurrent draft state on the hybrid arch."""
    cfg = smoke_variant(get_config(arch))
    ref, baseline = _serve(cfg, _requests(cfg))
    drafter = ModelDrafter(cfg, params=T.init(cfg, jax.random.key(0)),
                           slots=2, max_len=16 + SPEC_K, page_size=4,
                           dtype=jnp.float32)
    got, sched = _serve(cfg, _requests(cfg), spec_k=SPEC_K, drafter=drafter)
    assert got == ref, arch
    st = sched.stats
    assert st["spec_proposed"] > 0
    assert st["spec_accepted"] == st["spec_proposed"], st
    # >1 token per fused step on average, and strictly fewer steps
    assert st["decode_tokens"] > st["decode_steps"]
    assert st["decode_steps"] < baseline.stats["decode_steps"]


def test_spec_model_drafter_desynced_params_still_lossless():
    """A draft model with DIFFERENT params (a bad guesser) may be rejected
    every round but can never change the emitted streams."""
    cfg = smoke_variant(get_config("olmo-1b"))
    ref, _ = _serve(cfg, _requests(cfg))
    drafter = ModelDrafter(cfg, params=T.init(cfg, jax.random.key(99)),
                           slots=2, max_len=16 + SPEC_K, page_size=4,
                           dtype=jnp.float32)
    got, _ = _serve(cfg, _requests(cfg), spec_k=SPEC_K, drafter=drafter)
    assert got == ref


def test_spec_eos_truncation_parity():
    """EOS landing inside a batch of accepted tokens truncates the stream
    exactly where the one-token baseline stops, and the slot is recycled."""
    cfg = smoke_variant(get_config("olmo-1b"))
    probe, _ = _serve(cfg, _requests(cfg, lens=[8, 7, 6]))
    eos = probe[0][1]                   # request 0's 2nd token ends it early

    def truncate(toks):
        return toks[:toks.index(eos) + 1] if eos in toks else toks

    ref, _ = _serve(cfg, _requests(cfg, lens=[8, 7, 6]), eos=eos)
    drafter = ModelDrafter(cfg, params=T.init(cfg, jax.random.key(0)),
                           slots=2, max_len=16 + SPEC_K, page_size=4,
                           dtype=jnp.float32)
    got, sched = _serve(cfg, _requests(cfg, lens=[8, 7, 6]), eos=eos,
                        spec_k=SPEC_K, drafter=drafter)
    assert got == ref
    for rid in (0, 1, 2):
        assert got[rid] == truncate(probe[rid]), rid
    served = sorted(r for h in sched.slot_history.values() for r in h)
    assert served == [0, 1, 2]


def test_spec_respects_budget():
    """max_new is never overshot even when more drafts would match: the
    per-slot draft cap keeps consumed <= remaining budget."""
    cfg = smoke_variant(get_config("olmo-1b"))
    ref, _ = _serve(cfg, _requests(cfg, gen=2))
    drafter = ModelDrafter(cfg, params=T.init(cfg, jax.random.key(0)),
                           slots=2, max_len=16 + SPEC_K, page_size=4,
                           dtype=jnp.float32)
    got, _ = _serve(cfg, _requests(cfg, gen=2), spec_k=SPEC_K,
                    drafter=drafter)
    assert got == ref
    assert all(len(v) == 2 for v in got.values())


def test_spec_coexists_with_chunked_prefill():
    """A long prompt chunk-prefilled into a freed slot while other slots
    run SPECULATIVE decode rounds: the active mask keeps mid-admission
    slots out of the verify step and every stream matches the baseline."""
    cfg = smoke_variant(get_config("olmo-1b"))
    rng = np.random.default_rng(3)
    mk = lambda rid, n, g: Request(
        rid=rid, max_new=g,
        prompt=rng.integers(0, cfg.vocab_size, n).astype(np.int32))
    queue = lambda: [mk(0, 4, 10), mk(1, 4, 2), mk(2, 16, 3)]
    rng = np.random.default_rng(3)
    ref, _ = _serve(cfg, queue(), max_len=32)
    rng = np.random.default_rng(3)
    drafter = ModelDrafter(cfg, params=T.init(cfg, jax.random.key(0)),
                           slots=2, max_len=32 + SPEC_K, page_size=4,
                           dtype=jnp.float32, catch_up_chunk=4)
    got, sched = _serve(cfg, queue(), max_len=32, spec_k=SPEC_K,
                        drafter=drafter, prefill_chunk=4)
    assert got == ref
    assert sched.stats["prefill_chunks"] >= 4
    assert sched.stats["spec_accepted"] > 0


def test_spec_requires_paged_engine():
    cfg = smoke_variant(get_config("olmo-1b"))
    eng = InferenceEngine(cfg, slots=2, max_len=16, dtype=jnp.float32)
    state = eng.init_state(T.init(cfg, jax.random.key(0)))
    with pytest.raises(ValueError, match="paged"):
        Scheduler(eng, state, spec_k=SPEC_K)


def test_model_drafter_survives_missed_release():
    """Stale-context regression: a recycled slot whose NEW request's
    context is already LONGER than the old committed position slipped
    past the length-only reuse check — the drafter teacher-forced the
    new tail onto the old request's committed KV and proposed garbage.
    The committed-prefix fingerprint catches the mismatch and re-assigns;
    proposals must match a fresh drafter's even when ``release`` was
    never called."""
    cfg = smoke_variant(get_config("olmo-1b"))
    params = T.init(cfg, jax.random.key(0))
    mk = lambda: ModelDrafter(cfg, params=params, slots=1, max_len=32,
                              page_size=4, dtype=jnp.float32)
    rng = np.random.default_rng(13)
    ctx_a = rng.integers(0, cfg.vocab_size, 10).astype(np.int32)
    # ctx_b shares ctx_a's first token but is otherwise new — and LONGER
    # than ctx_a, so a length-only heuristic sees a plausible catch-up
    ctx_b = np.concatenate(
        [ctx_a[:1], rng.integers(0, cfg.vocab_size, 11).astype(np.int32)])
    stale = mk()
    stale.propose({0: (ctx_a, 3)})              # request 1 occupies slot 0
    got = stale.propose({0: (ctx_b, 3)})        # request 2, NO release()
    want = mk().propose({0: (ctx_b, 3)})
    assert got[0].tolist() == want[0].tolist()


def test_ngram_drafter_proposes_continuation_of_repeats():
    d = NgramDrafter(max_ngram=3)
    ctx = np.asarray([5, 6, 7, 9, 5, 6, 7], np.int32)
    out = d.propose({0: (ctx, 2)})
    assert out[0].tolist() == [9, 5]    # follows the earlier [5, 6, 7]
    # no repeated suffix anywhere -> silence, not a guess
    assert d.propose({0: (np.arange(8, dtype=np.int32), 4)}) == {}


# ---------------------------------------------------------------------------
# 8-device mesh: the acceptance bar
# ---------------------------------------------------------------------------
needs8 = pytest.mark.skipif(len(jax.devices()) < 8,
                            reason="needs 8 host devices (CI sets XLA_FLAGS)")


@needs8
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "gemma2-2b",
                                  "recurrentgemma-2b"])
def test_spec_parity_on_mesh(arch):
    """On the (4, 2) mesh with ragged prompts, slot reuse and a partially
    correct drafter, speculative streams bit-match the spec_k=0 mesh run
    across attention-only, local/global and recurrent-hybrid archs."""
    cfg = smoke_variant(get_config(arch))
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    ref, _ = _serve(cfg, _requests(cfg), slots=4, mesh=mesh)
    got, sched = _serve(cfg, _requests(cfg), slots=4, mesh=mesh,
                        spec_k=SPEC_K,
                        drafter=ScriptedDrafter(_truth(cfg, ref),
                                                cfg.vocab_size, 2))
    assert got == ref, arch
    assert sched.stats["spec_accepted"] > 0
