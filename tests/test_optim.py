"""Optimizer + schedule behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig
from repro.optim import clip_by_global_norm, make_optimizer
from repro.optim.schedules import make_schedule


def test_adamw_minimizes_quadratic():
    tc = TrainConfig(learning_rate=0.1, total_steps=200, warmup_steps=5,
                     weight_decay=0.0)
    init, update = make_optimizer(tc)
    params = {"w": jnp.asarray([3.0, -2.0]), "nested": ({"b": jnp.ones(3)},)}
    target = jax.tree.map(jnp.zeros_like, params)
    opt = init(params)
    loss = lambda p: sum(jnp.sum(x ** 2) for x in jax.tree.leaves(p))
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, m = update(params, g, opt)
    assert float(loss(params)) < 1e-3
    assert int(opt.step) == 150


def test_sgd_runs():
    tc = TrainConfig(learning_rate=0.05, optimizer="sgd", total_steps=100,
                     warmup_steps=1)
    init, update = make_optimizer(tc)
    params = {"w": jnp.asarray([1.0])}
    opt = init(params)
    for _ in range(50):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, _ = update(params, g, opt)
    assert abs(float(params["w"][0])) < 0.5


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 20.0)
    got = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    np.testing.assert_allclose(got, 1.0, rtol=1e-5)
    # under the cap: unchanged
    g2 = {"a": jnp.ones(4) * 0.1}
    c2, _ = clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(np.asarray(c2["a"]), 0.1, rtol=1e-6)


def test_schedules():
    for kind in ("cosine", "linear", "constant"):
        tc = TrainConfig(learning_rate=1e-3, schedule=kind,
                         warmup_steps=10, total_steps=100)
        s = make_schedule(tc)
        assert float(s(0)) == 0.0 if kind != "constant" else True
        np.testing.assert_allclose(float(s(10)), 1e-3, rtol=1e-5)
        if kind != "constant":
            assert float(s(100)) < 1e-4
