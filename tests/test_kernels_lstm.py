"""Fused LSTM cell kernel: sweep vs oracle + equivalence with the model's
pure-JAX cell (core/temporal.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.lstm_cell.kernel import lstm_cell_pallas
from repro.kernels.lstm_cell.ops import lstm_cell_fused, pack_weights
from repro.kernels.lstm_cell.ref import lstm_cell_ref


def _mk(rng, B, D, H, dtype):
    x = jnp.asarray(rng.normal(0, 1, (B, D)), dtype)
    h = jnp.asarray(rng.normal(0, 1, (B, H)), dtype)
    c = jnp.asarray(rng.normal(0, 1, (B, H)), dtype)
    wx = jnp.asarray(rng.normal(0, 0.2, (D, 4, H)), jnp.float32)
    wh = jnp.asarray(rng.normal(0, 0.2, (H, 4, H)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 0.1, (4, H)), jnp.float32)
    return x, h, c, wx, wh, b


@pytest.mark.parametrize("B,D,H", [(1, 8, 16), (7, 48, 160), (8, 64, 128),
                                   (3, 100, 200), (16, 32, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sweep_matches_ref(rng, B, D, H, dtype):
    args = _mk(rng, B, D, H, dtype)
    h1, c1 = lstm_cell_pallas(*args, interpret=True)
    h2, c2 = lstm_cell_ref(*args)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(c1, np.float32),
                               np.asarray(c2, np.float32), atol=tol)


def test_matches_model_cell(rng, key):
    """Kernel == core/temporal.py lstm_cell under the layout adapter."""
    from repro.core.temporal import lstm_cell, lstm_cell_params
    from repro.distributed.sharding import ParamFactory
    D, H, B = 24, 32, 5
    params = lstm_cell_params(ParamFactory(key), D, H)
    x = jnp.asarray(rng.normal(0, 1, (B, D)).astype("float32"))
    h = jnp.asarray(rng.normal(0, 1, (B, H)).astype("float32"))
    c = jnp.asarray(rng.normal(0, 1, (B, H)).astype("float32"))
    want_h, want_c = lstm_cell(params, x, h, c)
    wx, wh, b = pack_weights(params["wx"], params["wh"], params["b"])
    got_h, got_c = lstm_cell_fused(x, h, c, wx, wh, b)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h), atol=2e-6)
    np.testing.assert_allclose(np.asarray(got_c), np.asarray(want_c), atol=2e-6)
