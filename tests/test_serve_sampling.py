"""Per-request sampling over the serving stack (serve/sampling.py).

The determinism contract generalizes from greedy: every stream is a
function of (prompt, sampling params, seed) ALONE.  Sampled streams must
be bit-identical across batch compositions, arrival orders, paged vs
contiguous layouts, chunked vs whole prefill, speculation depth 0 vs K,
preemption, prefix-cache hits, and mesh shapes — because draw keys fold
by ABSOLUTE stream position, never by step count or slot id.  And
``temperature=0`` must stay bit-identical to the pre-sampling argmax
path (greedy slots ride the raw argmax even inside a mixed batch).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import transformer as T
from repro.serve import (
    InferenceEngine, NgramDrafter, Request, SamplingParams, Scheduler,
)
from repro.serve import sampling

PROMPT, GEN = 8, 6
LENS = [8, 5, 7, 6]

#: the canonical heterogeneous workload: per-request temps/filters/seeds
MIXED = [SamplingParams(temperature=0.8, top_p=0.9, seed=11),
         SamplingParams(),                                  # greedy
         SamplingParams(temperature=1.0, top_k=40, rep_penalty=1.3, seed=12),
         SamplingParams(temperature=0.6, top_k=8, top_p=0.95, seed=13)]


def _requests(cfg, lens=LENS, gen=GEN, seed=0, params=None):
    rng = np.random.default_rng(seed)
    reqs = []
    for i, n in enumerate(lens):
        sp = SamplingParams()
        if params is not None:
            sp = params[i % len(params)]
        reqs.append(Request(
            rid=i, max_new=gen, sampling=sp,
            prompt=rng.integers(0, cfg.vocab_size, n).astype(np.int32)))
    return reqs


def _serve(cfg, reqs, *, slots=2, mesh=None, max_len=16, sched_kw=None,
           **kw):
    eng = InferenceEngine(cfg, slots=slots, mesh=mesh, dtype=jnp.float32,
                          max_len=max_len, **kw)
    state = eng.init_state(T.init(cfg, jax.random.key(0)))
    sched = Scheduler(eng, state, **(sched_kw or {}))
    return sched.run(reqs), sched


# ---------------------------------------------------------------------------
# draw(): the vectorized per-slot sampler, unit-level
# ---------------------------------------------------------------------------
def _draw(logits, **over):
    S, V = logits.shape
    kw = dict(
        keys=jnp.tile(jnp.asarray(jax.random.PRNGKey(0))[None], (S, 1)),
        positions=jnp.zeros((S,), jnp.int32),
        temperature=jnp.ones((S,), jnp.float32),
        top_k=jnp.zeros((S,), jnp.int32),
        top_p=jnp.ones((S,), jnp.float32),
        rep_penalty=jnp.ones((S,), jnp.float32),
        presence=jnp.zeros((S, V), bool))
    kw.update(over)
    return np.asarray(sampling.draw(jnp.asarray(logits), **kw))


def test_draw_top_k_one_is_argmax():
    rng = np.random.default_rng(0)
    logits = rng.normal(0, 3, (4, 32)).astype(np.float32)
    for pos in (0, 7, 100):
        got = _draw(logits, top_k=jnp.ones((4,), jnp.int32),
                    positions=jnp.full((4,), pos, jnp.int32))
        assert (got == logits.argmax(-1)).all()


def test_draw_tiny_top_p_is_argmax():
    rng = np.random.default_rng(1)
    logits = rng.normal(0, 3, (4, 32)).astype(np.float32)
    got = _draw(logits, top_p=jnp.full((4,), 1e-6, jnp.float32))
    assert (got == logits.argmax(-1)).all()


def test_draw_top_k_restricts_support():
    rng = np.random.default_rng(2)
    logits = rng.normal(0, 1, (64, 32)).astype(np.float32)
    got = _draw(logits, top_k=jnp.full((64,), 3, jnp.int32),
                positions=jnp.arange(64, dtype=jnp.int32))
    for i, t in enumerate(got):
        assert int(t) in np.argsort(-logits[i])[:3], i
    assert len(set(got.tolist())) > 1            # not collapsed to argmax


def test_draw_rep_penalty_flips_present_winner():
    """A present token barely ahead of an absent one loses under penalty:
    with top_k=1 the draw is the post-penalty argmax, so the flip is
    observable deterministically."""
    logits = np.full((1, 8), -5.0, np.float32)
    logits[0, 2], logits[0, 5] = 2.0, 1.9        # 2 wins raw
    presence = np.zeros((1, 8), bool)
    presence[0, 2] = True                        # ...but 2 was emitted
    got = _draw(logits, top_k=jnp.ones((1,), jnp.int32),
                presence=jnp.asarray(presence),
                rep_penalty=jnp.full((1,), 2.0, jnp.float32))
    assert got[0] == 5
    # penalty 1.0 is the off switch even with presence set
    got = _draw(logits, top_k=jnp.ones((1,), jnp.int32),
                presence=jnp.asarray(presence))
    assert got[0] == 2


def test_draw_position_folds_decorrelate():
    """Uniform logits: the positional fold must yield different draws
    across positions (same base key), and identical draws on replay."""
    logits = np.zeros((64, 32), np.float32)
    a = _draw(logits, positions=jnp.arange(64, dtype=jnp.int32))
    b = _draw(logits, positions=jnp.arange(64, dtype=jnp.int32))
    assert (a == b).all()                        # replay-deterministic
    assert len(set(a.tolist())) > 4              # positions decorrelate


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1).validate()
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0).validate()
    with pytest.raises(ValueError, match="rep_penalty"):
        SamplingParams(rep_penalty=0.0).validate()
    SamplingParams(temperature=0.8, top_k=5, top_p=0.5).validate()
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.5).greedy


def test_scheduler_rejects_bad_sampling_before_serving():
    cfg = smoke_variant(get_config("olmo-1b"))
    reqs = _requests(cfg, [PROMPT, PROMPT])
    reqs[1].sampling = SamplingParams(top_p=2.0)
    with pytest.raises(ValueError, match="request 1"):
        _serve(cfg, reqs)
    assert reqs[0].generated == []               # fail-fast, nothing served


# ---------------------------------------------------------------------------
# temperature=0 IS the greedy path, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "gemma2-2b",
                                  "recurrentgemma-2b"])
def test_temp_zero_bit_matches_default_greedy(arch):
    """Explicit temperature=0 params (any seed) must be indistinguishable
    from the default argmax path across attention-only, local/global and
    recurrent-hybrid archs — the acceptance bar for not perturbing the
    pre-sampling serving behavior."""
    cfg = smoke_variant(get_config(arch))
    ref, _ = _serve(cfg, _requests(cfg), paged=True, page_size=4)
    zeros = [SamplingParams(temperature=0.0, seed=99)]
    got, _ = _serve(cfg, _requests(cfg, params=zeros), paged=True,
                    page_size=4)
    assert got == ref, arch


# ---------------------------------------------------------------------------
# sampled-stream determinism: (prompt, params, seed) is the whole story
# ---------------------------------------------------------------------------
def test_sampled_replay_deterministic_and_seed_sensitive():
    cfg = smoke_variant(get_config("olmo-1b"))
    a, _ = _serve(cfg, _requests(cfg, params=MIXED), paged=True, page_size=4)
    b, _ = _serve(cfg, _requests(cfg, params=MIXED), paged=True, page_size=4)
    assert a == b
    bumped = [SamplingParams(temperature=p.temperature, top_k=p.top_k,
                             top_p=p.top_p, rep_penalty=p.rep_penalty,
                             seed=p.seed + 1) for p in MIXED]
    c, _ = _serve(cfg, _requests(cfg, params=bumped), paged=True,
                  page_size=4)
    assert any(c[r] != a[r] for r in (0, 2, 3))  # sampled rows moved
    assert c[1] == a[1]                          # the greedy row did not


def test_sampled_batched_matches_solo_and_any_arrival_order():
    cfg = smoke_variant(get_config("olmo-1b"))
    batched, _ = _serve(cfg, _requests(cfg, params=MIXED), paged=True,
                        page_size=4)
    for i in range(len(LENS)):
        solo, _ = _serve(cfg, [_requests(cfg, params=MIXED)[i]], slots=1,
                         paged=True, page_size=4)
        assert solo[i] == batched[i], i
    shuffled = _requests(cfg, params=MIXED)
    shuffled = [shuffled[i] for i in (3, 1, 0, 2)]
    reordered, _ = _serve(cfg, shuffled, paged=True, page_size=4)
    assert reordered == batched


def test_sampled_paged_matches_contiguous():
    cfg = smoke_variant(get_config("olmo-1b"))
    ref, _ = _serve(cfg, _requests(cfg, params=MIXED))
    got, _ = _serve(cfg, _requests(cfg, params=MIXED), paged=True,
                    page_size=4)
    assert got == ref


def test_sampled_chunked_prefill_matches_whole():
    """Chunk boundaries change WHERE the prompt's final forward runs, not
    the absolute position its emitted token samples at."""
    cfg = smoke_variant(get_config("olmo-1b"))
    ref, _ = _serve(cfg, _requests(cfg, params=MIXED), paged=True,
                    page_size=4)
    got, sched = _serve(cfg, _requests(cfg, params=MIXED), paged=True,
                        page_size=4, prefill_chunk=3)
    assert got == ref
    assert sched.stats["prefill_chunks"] >= 2 * len(LENS)


def test_sampled_greedy_mix_leaves_greedy_rows_untouched():
    """Greedy rows co-batched with sampled neighbours must bit-match the
    all-greedy run — the sampled pipeline may never leak into a
    temperature-0 slot (and slot reuse sampled -> greedy must reset)."""
    cfg = smoke_variant(get_config("olmo-1b"))
    all_greedy, _ = _serve(cfg, _requests(cfg), paged=True, page_size=4)
    mixed, _ = _serve(cfg, _requests(cfg, params=MIXED), paged=True,
                      page_size=4)
    assert mixed[1] == all_greedy[1]
    # 4 requests through 2 slots: rid 2/3 reuse rid 0/1's slots, so a
    # sampled slot is reclaimed by another config either way
    assert mixed[0] != all_greedy[0]             # sanity: sampling sampled


# ---------------------------------------------------------------------------
# lossless speculation under sampling: spec-k 0 == spec-k K at equal seeds
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["olmo-1b", "recurrentgemma-2b"])
def test_sampled_spec_matches_nonspec(arch):
    """Rejection-sampling verification with positional keys: whatever the
    drafter proposes, the emitted sampled streams bit-match the spec_k=0
    run — the same losslessness bar the greedy path pins, now for
    temperature > 0."""
    cfg = smoke_variant(get_config(arch))
    ref, _ = _serve(cfg, _requests(cfg, params=MIXED), paged=True,
                    page_size=4)
    got, sched = _serve(cfg, _requests(cfg, params=MIXED), paged=True,
                        page_size=4,
                        sched_kw={"spec_k": 3, "drafter": NgramDrafter()})
    assert got == ref, arch
    assert sched.stats["spec_proposed"] >= 0     # acceptance is incidental


def test_sampled_spec_accepts_correct_drafts():
    """An oracle drafter proposing the true sampled continuation must see
    its drafts accepted — the rejection rule degenerates to exact match
    for our deterministic positional draws, so acceptance (not just
    parity) proves the verify-path draws equal the decode-path draws."""
    cfg = smoke_variant(get_config("olmo-1b"))
    params = [SamplingParams(temperature=0.8, top_p=0.9, seed=21)]
    ref, _ = _serve(cfg, _requests(cfg, params=params), paged=True,
                    page_size=4)

    class Oracle:
        def propose(self, wants):
            out = {}
            for slot, (ctx, k) in wants.items():
                ctx = np.asarray(ctx, np.int32)
                for r in _requests(cfg, params=params):
                    p = np.asarray(r.prompt, np.int32)
                    if len(ctx) >= len(p) and (ctx[:len(p)] == p).all():
                        n = len(ctx) - len(p)
                        cont = ref[r.rid][n:n + k]
                        if cont:
                            out[slot] = np.asarray(cont, np.int32)
                        break
            return out

        def release(self, slot):
            pass

    got, sched = _serve(cfg, _requests(cfg, params=params), paged=True,
                        page_size=4,
                        sched_kw={"spec_k": 3, "drafter": Oracle()})
    assert got == ref
    st = sched.stats
    assert st["spec_accepted"] == st["spec_proposed"] > 0, st


# ---------------------------------------------------------------------------
# sampled streams survive the page-pool policies bit for bit
# ---------------------------------------------------------------------------
def test_sampled_preemption_matches_deferred_run():
    cfg = smoke_variant(get_config("olmo-1b"))
    params = [SamplingParams(temperature=0.9, top_p=0.9, seed=31),
              SamplingParams(temperature=0.7, top_k=16, seed=32)]
    mk = lambda: [Request(rid=i, max_new=4 + 2 * i,
                          sampling=params[i % 2],
                          prompt=np.random.default_rng(7 + i).integers(
                              0, cfg.vocab_size, 10 + i).astype(np.int32))
                  for i in range(3)]
    ref, base = _serve(cfg, mk(), max_len=24, paged=True, page_size=8,
                       num_pages=4)
    got, sched = _serve(cfg, mk(), max_len=24, paged=True, page_size=8,
                        num_pages=4, sched_kw={"preempt": True})
    assert got == ref
    assert base.stats["deferred_admissions"] > 0
    assert sched.stats["preemptions"] >= 1       # the swap blob carried the
    assert sched.stats["restores"] >= 1          # sampling rows + presence


def test_sampled_prefix_cache_hit_matches_cold_prefill():
    """A sampled request resuming past cached shared-prefix pages samples
    at the same absolute positions a cold prefill would — the skipped
    prefix changes compute, never draws."""
    cfg = smoke_variant(get_config("qwen2-1.5b"))
    rng = np.random.default_rng(5)
    pre = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    mk = lambda: [Request(
        rid=i, max_new=GEN,
        sampling=SamplingParams(temperature=0.8, top_p=0.9, seed=41 + i),
        prompt=np.concatenate([pre, rng.integers(
            0, cfg.vocab_size, t).astype(np.int32)]))
        for i, t in enumerate([4, 4, 6])]
    rng = np.random.default_rng(5)
    pre = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    ref, _ = _serve(cfg, mk(), max_len=48, paged=True, page_size=8,
                    prefill_chunk=6)
    rng = np.random.default_rng(5)
    pre = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    got, sched = _serve(cfg, mk(), max_len=48, paged=True, page_size=8,
                        prefill_chunk=6, sched_kw={"prefix_cache": True})
    assert got == ref
    assert sched.stats["prefix_hits"] >= 1
    assert sched.stats["prefix_hit_tokens"] >= 24


# ---------------------------------------------------------------------------
# 8-device mesh
# ---------------------------------------------------------------------------
needs8 = pytest.mark.skipif(len(jax.devices()) < 8,
                            reason="needs 8 host devices (CI sets XLA_FLAGS)")


@needs8
@pytest.mark.xfail(
    strict=False,
    reason="known pre-existing failure (see ROADMAP.md Status): the mesh + "
           "spec + sampled leg diverges from the 1x1 reference; present at "
           "the PR-8 seed")
def test_sampled_mesh_matches_single_device():
    """Mixed greedy/sampled streams off the (4, 2)-sharded state bit-match
    the default 1x1-mesh engine, speculation on."""
    cfg = smoke_variant(get_config("qwen2-1.5b"))
    ref, _ = _serve(cfg, _requests(cfg, params=MIXED), slots=4, paged=True,
                    page_size=4)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    got, _ = _serve(cfg, _requests(cfg, params=MIXED), slots=4, mesh=mesh,
                    paged=True, page_size=4,
                    sched_kw={"spec_k": 3, "drafter": NgramDrafter()})
    assert got == ref
