import numpy as np

from repro.metrics import Meter, nse


def test_nse_perfect():
    obs = np.array([1.0, 2.0, 3.0, 4.0])
    assert float(nse(obs, obs)) == 1.0


def test_nse_mean_predictor_is_zero():
    obs = np.array([1.0, 2.0, 3.0, 4.0])
    sim = np.full_like(obs, obs.mean())
    np.testing.assert_allclose(float(nse(sim, obs)), 0.0, atol=1e-6)


def test_nse_bad_predictor_negative():
    obs = np.array([1.0, 2.0, 3.0, 4.0])
    sim = -obs
    assert float(nse(sim, obs)) < 0


def test_meter():
    m = Meter()
    m.update(loss=1.0)
    m.update(loss=3.0)
    assert m.mean("loss") == 2.0 and m.last("loss") == 3.0
    assert m.elapsed() >= 0
