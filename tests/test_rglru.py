"""RG-LRU: associative scan vs explicit step loop; stability."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.distributed.sharding import ParamFactory
from repro.models import rglru as R


def test_scan_matches_step_loop(rng, key):
    cfg = smoke_variant(get_config("recurrentgemma-2b"))
    params = R.rglru_params(ParamFactory(key), cfg)
    T = 14
    x = jnp.asarray(rng.normal(0, 1, (2, T, cfg.d_model)).astype("float32"))
    full, stateT = R.rglru_block(params, cfg, x, return_state=True)
    state = R.init_rglru_state(cfg, 2)
    outs = []
    for t in range(T):
        o, state = R.rglru_decode_step(params, cfg, x[:, t:t + 1], state)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full), atol=3e-5)
    np.testing.assert_allclose(np.asarray(state.h), np.asarray(stateT.h),
                               atol=3e-5)


def test_recurrence_is_stable(rng, key):
    """|a_t| <= 1 guarantees bounded state for bounded inputs."""
    cfg = smoke_variant(get_config("recurrentgemma-2b"))
    params = R.rglru_params(ParamFactory(key), cfg)
    x = jnp.asarray(rng.normal(0, 5, (1, 500, cfg.d_model)).astype("float32"))
    out = R.rglru_block(params, cfg, x)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_grad_finite(rng, key):
    cfg = smoke_variant(get_config("recurrentgemma-2b"))
    params = R.rglru_params(ParamFactory(key), cfg)
    x = jnp.asarray(rng.normal(0, 1, (2, 16, cfg.d_model)).astype("float32"))
    g = jax.grad(lambda p: jnp.sum(R.rglru_block(p, cfg, x) ** 2))(params)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))
