"""Unified training engine (repro/train/): microbatch parity, stacked
IP-D parity vs the seed step, TrainState checkpoint round-trip, and the
multi-device sharded path when the host exposes >= 8 devices."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_config, smoke_variant
from repro.core import domst
from repro.optim import make_optimizer
from repro.train import Engine, TrainState


def _batch(rng, n=8, T=30, P=64):
    return {
        "precip": jnp.asarray(rng.normal(0, 1, (n, T, P)).astype("float32")),
        "target_day": jnp.asarray(rng.normal(0, 1, (n, P)).astype("float32")),
        "dist": jnp.asarray(rng.uniform(0, 1, (n, P)).astype("float32")),
        "discharge": jnp.asarray(rng.normal(0, 1, n).astype("float32")),
    }


def test_grad_accum_matches_full_batch(rng):
    """accum_steps=4 must produce the same update and loss as one full
    batch (loss is a mean; SGD so bf16/adam normalization noise is out)."""
    cfg = get_config("domst")
    tc = TrainConfig(learning_rate=1e-2, total_steps=10, warmup_steps=1,
                     optimizer="sgd")
    b = _batch(rng, n=8)
    outs = {}
    for A in (1, 4):
        eng = Engine.for_domst(cfg, tc, accum_steps=A)
        state = eng.init_state(jax.random.key(0),
                               domst.init(cfg, jax.random.key(0)))
        state, m = eng.step(state, b)
        outs[A] = (state.params, float(m["loss"]))
    np.testing.assert_allclose(outs[1][1], outs[4][1], rtol=2e-5)
    for a, c in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   atol=1e-5, rtol=1e-5)


def test_accum_requires_divisible_minibatch(rng):
    cfg = get_config("domst")
    eng = Engine.for_domst(cfg, TrainConfig(), accum_steps=3)
    state = eng.init_state(jax.random.key(0),
                           domst.init(cfg, jax.random.key(0)))
    with pytest.raises(ValueError, match="divisible"):
        eng.step(state, _batch(rng, n=8))


def test_stacked_engine_matches_seed_step(rng):
    """Engine-driven stacked (IP-D) training reproduces the seed
    jit(vmap) step's losses and params exactly over several steps."""
    cfg = get_config("domst")
    tc = TrainConfig(learning_rate=1e-3, total_steps=10, warmup_steps=1)
    eng = Engine.for_domst(cfg, tc, stacked=True)
    state = eng.init_state(jax.random.key(1),
                           domst.init_stacked(cfg, jax.random.key(1), 2))

    ref_step = domst.make_reference_stacked_step(cfg, tc)
    ref_params = domst.init_stacked(cfg, jax.random.key(1), 2)
    ref_opt = jax.vmap(make_optimizer(tc)[0])(ref_params)

    for i in range(3):
        b = {k: jnp.stack([v, v]) for k, v in _batch(rng).items()}
        state, m = eng.step(state, b)
        ref_params, ref_opt, m_ref = ref_step(ref_params, ref_opt, b)
        np.testing.assert_allclose(np.asarray(m["loss"]),
                                   np.asarray(m_ref["loss"]), atol=1e-6)
    for a, c in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(ref_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-6)
    assert int(state.step) == 3


def test_trainstate_checkpoint_roundtrip(tmp_path, rng):
    """Full TrainState (params + moments + counters + rng) round-trips."""
    cfg = get_config("domst")
    tc = TrainConfig(learning_rate=1e-3, total_steps=10, warmup_steps=1)
    eng = Engine.for_domst(cfg, tc)
    state = eng.init_state(jax.random.key(0),
                           domst.init(cfg, jax.random.key(0)))
    state, _ = eng.step(state, _batch(rng))
    path = str(tmp_path / "state.npz")
    eng.save(path, state)
    blank = eng.init_state(jax.random.key(9),
                           domst.init(cfg, jax.random.key(9)))
    restored = eng.restore(path, blank)
    for a, c in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    assert int(restored.step) == 1
    assert int(restored.opt_state.step) == 1
    # and the restored state trains on
    _, m = eng.step(restored, _batch(rng))
    assert np.isfinite(float(m["loss"]))


def test_lm_engine_trains(key):
    """LM drive path: loss decreases through the engine with accum=2."""
    from repro.data.tokens import synthetic_token_batch
    from repro.models import transformer as tfm
    cfg = smoke_variant(get_config("qwen2-1.5b"))
    tc = TrainConfig(learning_rate=3e-3, total_steps=40, warmup_steps=4,
                     remat="block")
    eng = Engine.for_lm(cfg, tc, accum_steps=2)
    state = eng.init_state(key, tfm.init(cfg, key))
    losses = []
    for i in range(25):
        b = {k: jnp.asarray(v)
             for k, v in synthetic_token_batch(cfg, 4, 32, seed=i).items()}
        state, m = eng.step(state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, (losses[0], losses[-1])


@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 host devices (CI sets XLA_FLAGS)")
def test_stacked_engine_shards_watersheds_on_mesh(rng):
    """On a (4, 2) mesh the watershed axis really shards over "data" and
    the engine's numerics match the single-device reference."""
    cfg = get_config("domst")
    tc = TrainConfig(learning_rate=1e-3, total_steps=10, warmup_steps=1)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    eng = Engine.for_domst(cfg, tc, mesh=mesh, stacked=True)
    state = eng.init_state(jax.random.key(1),
                           domst.init_stacked(cfg, jax.random.key(1), 4))
    sharding = jax.tree.leaves(state.params)[0].sharding
    spec = sharding.spec
    assert spec and spec[0] == "data", spec
    b1 = _batch(rng)
    b = {k: jnp.stack([v] * 4) for k, v in b1.items()}
    state, m = eng.step(state, b)

    ref_step = domst.make_reference_stacked_step(cfg, tc)
    ref_params = domst.init_stacked(cfg, jax.random.key(1), 4)
    ref_opt = jax.vmap(make_optimizer(tc)[0])(ref_params)
    _, _, m_ref = ref_step(ref_params, ref_opt, b)
    np.testing.assert_allclose(np.asarray(m["loss"]),
                               np.asarray(m_ref["loss"]), atol=1e-5)
