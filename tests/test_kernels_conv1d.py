"""Causal depthwise conv1d kernel: sweep vs oracle + causality property."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv1d.kernel import causal_conv1d_pallas
from repro.kernels.conv1d.ops import causal_conv1d
from repro.kernels.conv1d.ref import causal_conv1d_ref


def _mk(rng, B, S, C, K, dtype):
    x = jnp.asarray(rng.normal(0, 1, (B, S, C)), dtype)
    w = jnp.asarray(rng.normal(0, 0.5, (K, C)), jnp.float32)
    b = jnp.asarray(rng.normal(0, 0.1, (C,)), jnp.float32)
    return x, w, b


@pytest.mark.parametrize("B,S,C,K", [
    (1, 8, 16, 2), (2, 64, 96, 4), (3, 17, 128, 4), (4, 130, 256, 3),
    (2, 31, 64, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["none", "silu"])
def test_sweep_matches_ref(rng, B, S, C, K, dtype, act):
    x, w, b = _mk(rng, B, S, C, K, dtype)
    got = causal_conv1d_pallas(x, w, b, activation=act, interpret=True)
    want = causal_conv1d_ref(x, w, b, activation=act)
    tol = 2e-6 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_long_sequence_chunked_path(rng):
    """S > _MAX_SEQ_PER_CALL exercises the tail-carrying wrapper."""
    x, w, b = _mk(rng, 2, 5000, 64, 4, jnp.float32)
    got = causal_conv1d(x, w, b, activation="silu")
    want = causal_conv1d_ref(x, w, b, activation="silu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_causality(rng):
    """Output at t must not depend on inputs after t."""
    x, w, b = _mk(rng, 1, 40, 32, 4, jnp.float32)
    y1 = causal_conv1d_ref(x, w, b)
    x2 = x.at[:, 20:].add(100.0)
    y2 = causal_conv1d_ref(x2, w, b)
    np.testing.assert_array_equal(np.asarray(y1[:, :20]),
                                  np.asarray(y2[:, :20]))
    got1 = causal_conv1d_pallas(x, w, b, interpret=True)
    got2 = causal_conv1d_pallas(x2, w, b, interpret=True)
    np.testing.assert_array_equal(np.asarray(got1[:, :20]),
                                  np.asarray(got2[:, :20]))
