"""SSD intra-chunk kernel: sweep vs oracle + equivalence with the model's
chunked path (ssm.ssd_chunked internals)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_chunk.kernel import ssd_chunk_pallas
from repro.kernels.ssd_chunk.ops import ssd_chunk_fused
from repro.kernels.ssd_chunk.ref import ssd_chunk_ref


def _mk(rng, BN, H, Q, N, P, dtype):
    C = jnp.asarray(rng.normal(0, 1, (BN, H, Q, N)), dtype)
    B = jnp.asarray(rng.normal(0, 1, (BN, H, Q, N)), dtype)
    x = jnp.asarray(rng.normal(0, 1, (BN, H, Q, P)), dtype)
    # decreasing log-decay cumsum (realistic: dA < 0)
    dA = jnp.asarray(np.cumsum(-rng.uniform(0.01, 0.3, (BN, H, Q)), -1),
                     jnp.float32)
    return C, B, x, dA


@pytest.mark.parametrize("BN,H,Q,N,P", [
    (2, 2, 8, 16, 8), (3, 4, 16, 32, 16), (1, 1, 64, 128, 64),
    (4, 3, 32, 16, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sweep_matches_ref(rng, BN, H, Q, N, P, dtype):
    C, B, x, dA = _mk(rng, BN, H, Q, N, P, dtype)
    y1, s1 = ssd_chunk_pallas(C, B, x, dA, interpret=True)
    y2, s2 = ssd_chunk_ref(C, B, x, dA)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2, np.float32),
                               atol=tol, rtol=tol)


def test_matches_model_chunk_math(rng):
    """Kernel output == the corresponding einsums in ssm.ssd_chunked."""
    Bsz, nc, Q, H, N, P = 2, 3, 8, 4, 16, 8
    Cc = jnp.asarray(rng.normal(0, 1, (Bsz, nc, Q, H, N)).astype("float32"))
    Bc = jnp.asarray(rng.normal(0, 1, (Bsz, nc, Q, H, N)).astype("float32"))
    xdt = jnp.asarray(rng.normal(0, 1, (Bsz, nc, Q, H, P)).astype("float32"))
    da = jnp.asarray(-rng.uniform(0.01, 0.3, (Bsz, nc, H, Q)), jnp.float32)
    dA_cs = jnp.cumsum(da, axis=-1)          # kernel takes the cumsum

    y_k, st_k = ssd_chunk_fused(Cc, Bc, xdt, dA_cs)

    # replicate ssd_chunked's steps 1-2 (model path segsums the RAW da)
    from repro.models.ssm import _segsum
    L = jnp.exp(_segsum(da))
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc)
    y_ref = jnp.einsum("bchqk,bckhp->bcqhp", scores * L, xdt)
    decay = jnp.exp(dA_cs[..., -1:] - dA_cs)
    st_ref = jnp.einsum("bcqhn,bchq,bcqhp->bchpn", Bc, decay, xdt)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_ref),
                               atol=2e-4, rtol=2e-4)
