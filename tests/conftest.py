"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device (the 512-device override is dryrun.py-only)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest

# hypothesis is a dev-only dependency (requirements-dev.txt): register the
# property-test profiles here, once, so every module shares them.  "ci"
# derandomizes (examples derived from the test function itself, no RNG, no
# example database) so tier-1 is bit-for-bit reproducible on CI; locally the
# default "dev" profile keeps randomized exploration.  Select explicitly
# with HYPOTHESIS_PROFILE=ci|dev.
try:
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("dev", max_examples=25, deadline=None)
    _hyp_settings.register_profile("ci", max_examples=25, deadline=None,
                                   derandomize=True, print_blob=True)
    _hyp_settings.load_profile(os.environ.get(
        "HYPOTHESIS_PROFILE", "ci" if os.environ.get("CI") else "dev"))
except ImportError:                      # pragma: no cover - optional dep
    pass


def pytest_configure(config):
    # the two slowest 8-device mesh-parity tests carry this marker so a
    # local quick loop can skip them (`pytest -m "not slow"`); tier-1 CI
    # runs everything — the marker documents cost, it never gates coverage
    config.addinivalue_line(
        "markers",
        "slow: slowest mesh-parity tests; deselect locally with "
        '-m "not slow"')


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)
