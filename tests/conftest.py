"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device (the 512-device override is dryrun.py-only)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)
