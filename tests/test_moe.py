"""MoE: gather/scatter dispatch vs dense-einsum reference; capacity drops;
load-balance loss."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.distributed.sharding import ParamFactory
from repro.models import moe as MOE
from repro.models.mlp import _act


def dense_moe_reference(params, cfg, x):
    """Compute every expert for every token, combine with top-k weights."""
    m = cfg.moe
    probs, topk_idx, topk_w = MOE.route(params["router"], x, m)
    g = jnp.einsum("bsd,edf->besf", x, params["w_gate"])
    u = jnp.einsum("bsd,edf->besf", x, params["w_up"])
    h = _act(g, cfg.act) * u
    y_all = jnp.einsum("besf,efd->besd", h, params["w_down"])   # (B,E,S,d)
    onehot = jax.nn.one_hot(topk_idx, m.num_experts, dtype=x.dtype)  # (B,S,K,E)
    w_se = jnp.einsum("bske,bsk->bse", onehot, topk_w.astype(x.dtype))
    y = jnp.einsum("bse,besd->bsd", w_se, y_all)
    if m.num_shared:
        from repro.models.mlp import mlp_block
        y = y + mlp_block(params["shared"], cfg.act, x)
    return y


@pytest.mark.parametrize("arch", ["deepseek-moe-16b", "qwen3-moe-30b-a3b"])
def test_dispatch_matches_dense_with_ample_capacity(rng, key, arch):
    cfg = smoke_variant(get_config(arch))
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = MOE.moe_params(ParamFactory(key), cfg)
    x = jnp.asarray(rng.normal(0, 1, (2, 12, cfg.d_model)).astype("float32"))
    got, aux = MOE.moe_block(params, cfg, x)
    want = dense_moe_reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
    assert float(aux) > 0


def test_capacity_drops_tokens(rng, key):
    """With capacity_factor ~0, most tokens are dropped -> output ~ shared only."""
    cfg = smoke_variant(get_config("qwen3-moe-30b-a3b"))
    cfg_lo = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=1e-6))
    cfg_hi = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = MOE.moe_params(ParamFactory(key), cfg_hi)
    x = jnp.asarray(rng.normal(0, 1, (1, 16, cfg.d_model)).astype("float32"))
    y_lo, _ = MOE.moe_block(params, cfg_lo, x)
    y_hi, _ = MOE.moe_block(params, cfg_hi, x)
    # low capacity keeps only ~1 token per expert -> strictly smaller norm
    assert float(jnp.sum(y_lo ** 2)) < float(jnp.sum(y_hi ** 2))


def test_load_balance_loss_prefers_uniform():
    m = dataclasses.replace(smoke_variant(get_config("qwen3-moe-30b-a3b")).moe)
    E, S = m.num_experts, 64
    # uniform routing
    probs_u = jnp.full((1, S, E), 1.0 / E)
    idx_u = jnp.stack([(jnp.arange(S) + i) % E for i in range(m.top_k)],
                      axis=-1)[None]
    # collapsed routing (everything to expert 0..k-1)
    probs_c = jnp.zeros((1, S, E)).at[..., 0].set(1.0)
    idx_c = jnp.tile(jnp.arange(m.top_k)[None, None], (1, S, 1))
    l_u = MOE.load_balance_loss(probs_u, idx_u, m)
    l_c = MOE.load_balance_loss(probs_c, idx_c, m)
    assert float(l_u) < float(l_c)


def test_dispatch_indices_respect_capacity(rng):
    m = dataclasses.replace(smoke_variant(get_config("qwen3-moe-30b-a3b")).moe)
    S = 32
    topk = jnp.asarray(rng.integers(0, m.num_experts, (S, m.top_k)), jnp.int32)
    cap = 3
    idx, valid, keep, slot = MOE._dispatch_indices(topk, m, cap)
    assert idx.shape == (m.num_experts, cap)
    # each expert receives at most cap valid tokens
    assert int(jnp.max(jnp.sum(valid, axis=1))) <= cap
    # kept (token, k) pairs have slots < cap
    assert bool(jnp.all(jnp.where(keep, slot, 0) < cap))
