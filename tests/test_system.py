"""End-to-end behaviour tests for the paper's system."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_paper_ablation_direction(key):
    """The paper's Fig-3 ordering on one synthetic watershed:
    Dom-ST (pixcon+multihead+P) >= Singlehead baseline after equal training.
    (Full 23-watershed comparison lives in benchmarks/fig3_nse.py.)"""
    from repro.configs import TrainConfig, get_config
    from repro.core import domst
    from repro.data import generate_watershed, make_training_windows
    from repro.data.pipeline import train_test_split
    from repro.optim import make_optimizer

    ws = generate_watershed(5, num_days=400)
    w = make_training_windows(ws)
    tr, te = train_test_split(w)
    te_j = {k: jnp.asarray(v) for k, v in te.items()}
    rng = np.random.default_rng(0)
    n = len(tr["discharge"])

    def train(name):
        cfg = get_config(name)
        tc = TrainConfig(learning_rate=3e-3, total_steps=240, warmup_steps=10)
        params = domst.init(cfg, key)
        step = domst.make_train_step(cfg, tc)
        opt = make_optimizer(tc)[0](params)
        for it in range(80):
            sl = rng.integers(0, n, 64)
            b = {k: jnp.asarray(v[sl]) for k, v in tr.items()}
            params, opt, _ = step(params, opt, b)
        return float(domst.evaluate(params, cfg, te_j)["nse"])

    nse_single = train("domst-singlehead")
    nse_domst = train("domst")
    # allow noise, but Dom-ST shouldn't be materially worse
    assert nse_domst > nse_single - 0.05, (nse_single, nse_domst)


def test_lm_training_reduces_loss(key):
    from repro.configs import TrainConfig, get_config, smoke_variant
    from repro.data.tokens import synthetic_token_batch
    from repro.models import transformer as tfm
    from repro.optim import make_optimizer
    cfg = smoke_variant(get_config("qwen2-1.5b"))
    tc = TrainConfig(learning_rate=3e-3, total_steps=60, warmup_steps=5)
    params = tfm.init(cfg, key)
    opt_init, opt_update = make_optimizer(tc)
    opt = opt_init(params)

    @jax.jit
    def step(p, o, b):
        (loss, _), g = jax.value_and_grad(
            lambda q: tfm.lm_loss(q, cfg, b), has_aux=True)(p)
        p, o, _ = opt_update(p, g, o)
        return p, o, loss

    losses = []
    for i in range(40):
        b = {k: jnp.asarray(v)
             for k, v in synthetic_token_batch(cfg, 4, 32, seed=i).items()}
        params, opt, loss = step(params, opt, b)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_serve_cli_roundtrip():
    """The serving launcher generates deterministic greedy tokens."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "olmo-1b",
         "--smoke", "--requests", "2", "--batch-size", "2",
         "--prompt-len", "8", "--gen", "4"],
        capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr[-800:]
    line = [l for l in out.stdout.splitlines() if l.startswith("{")][0]
    rec = json.loads(line)
    assert rec["requests"] == 2 and rec["tokens"] == 8


def test_train_cli_domst():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "domst",
         "--watersheds", "2", "--days", "120", "--epochs", "1"],
        capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr[-800:]
    assert "mean_nse" in out.stdout


def test_dryrun_small_mesh():
    """lower+compile a smoke config on a 2x2 host-device mesh (subprocess
    so the 4-device XLA flag doesn't leak into this test session)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax
from repro.configs import get_config, smoke_variant
from repro.launch.steps import lower_step
from repro.configs.base import TrainConfig
mesh = jax.make_mesh((2, 2), ("data", "model"))
for arch in ("olmo-1b", "deepseek-moe-16b", "mamba2-130m",
             "recurrentgemma-2b"):
    cfg = smoke_variant(get_config(arch))
    lowered, kind = lower_step(cfg, "train_4k", mesh,
                               tc=TrainConfig(remat="block"))
    c = lowered.compile()
    assert c.cost_analysis() is not None
    print("ok", arch, kind)
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=590)
    assert out.returncode == 0, (out.stdout[-500:], out.stderr[-2000:])
    assert out.stdout.count("ok ") == 4
