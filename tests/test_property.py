"""Hypothesis property tests on system invariants.

``hypothesis`` is a dev-only dependency (requirements-dev.txt); skip the
whole module instead of aborting collection when it's absent.  The
settings profiles live in ``tests/conftest.py`` ("ci" derandomizes so the
tier-1 run is reproducible); this module must NOT load its own profile.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
hnp = pytest.importorskip("hypothesis.extra.numpy")
st = pytest.importorskip("hypothesis.strategies")
given, settings = hypothesis.given, hypothesis.settings

from repro.core.partitioner import partition_pixels
from repro.kernels.conv1d.ref import causal_conv1d_ref
from repro.metrics import nse
from repro.models.layers import cross_entropy, softcap

floats = st.floats(-10, 10, allow_nan=False, width=32)


@given(hnp.arrays(np.float32, st.integers(4, 40), elements=floats),
       st.floats(0.1, 5.0))
def test_nse_shift_of_perfect_prediction(obs, eps):
    """NSE(obs, obs) == 1; adding error strictly lowers it (if var>0)."""
    if np.var(obs) < 1e-3:
        return
    assert abs(float(nse(obs, obs)) - 1.0) < 1e-5
    noisy = obs + eps * np.std(obs)
    assert float(nse(noisy, obs)) < 1.0


@given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=2,
                                               min_side=2, max_side=16),
                  elements=floats),
       st.floats(1.0, 50.0))
def test_softcap_bounds(x, cap):
    y = np.asarray(softcap(jnp.asarray(x), cap))
    assert np.all(np.abs(y) <= cap + 1e-4)
    # sign preserved away from subnormal underflow
    big = np.abs(x) > 1e-6
    assert np.all(np.sign(y)[big] == np.sign(x)[big])


@given(st.integers(1, 4), st.integers(2, 24), st.integers(2, 50))
def test_cross_entropy_nonneg_and_exact_for_onehot(b, v, s):
    rng = np.random.default_rng(b * 100 + v)
    logits = jnp.asarray(rng.normal(0, 3, (b, s, v)).astype("float32"))
    targets = jnp.asarray(rng.integers(0, v, (b, s)), jnp.int32)
    ce = float(cross_entropy(logits, targets))
    assert ce >= -1e-5
    # delta-function logits -> ce ~ 0
    hot = jax.nn.one_hot(targets, v) * 50.0
    assert float(cross_entropy(hot, targets)) < 1e-3


@given(st.integers(1, 4), st.integers(1, 4), st.integers(2, 8))
def test_partitioner_preserves_values(b, g_pow, t):
    g = 2 ** g_pow if 2 ** g_pow <= 8 else 8
    p = g * 4
    rng = np.random.default_rng(b)
    x = jnp.asarray(rng.normal(0, 1, (b, t, p)).astype("float32"))
    w = jnp.asarray(rng.uniform(0, 1, (b, p)).astype("float32"))
    parts, order = partition_pixels(x, w, g)
    # multiset of values preserved (it's a permutation along pixels)
    np.testing.assert_allclose(
        np.sort(np.asarray(parts).reshape(b, g * t * (p // g))),
        np.sort(np.asarray(x).reshape(b, t * p)), rtol=1e-6)


@given(st.integers(1, 3), st.integers(5, 40), st.integers(1, 4),
       st.integers(1, 4))
def test_conv_shift_equivariance(b, s, c, k):
    """Causal conv of a shifted signal == shifted conv (interior points)."""
    rng = np.random.default_rng(s)
    x = rng.normal(0, 1, (b, s, c)).astype("float32")
    w = rng.normal(0, 1, (k, c)).astype("float32")
    bias = np.zeros(c, "float32")
    y = np.asarray(causal_conv1d_ref(jnp.asarray(x), jnp.asarray(w),
                                     jnp.asarray(bias)))
    xs = np.roll(x, 1, axis=1)
    ys = np.asarray(causal_conv1d_ref(jnp.asarray(xs), jnp.asarray(w),
                                      jnp.asarray(bias)))
    # interior: y shifted by one equals conv of shifted input
    np.testing.assert_allclose(ys[:, k:], y[:, k - 1:-1], atol=1e-5)


@given(st.integers(0, 10_000))
def test_rglru_decay_in_unit_interval(seed):
    """a = exp(-c*softplus(lam)*r) in (0,1] for any lam, r in (0,1)."""
    rng = np.random.default_rng(seed)
    lam = rng.normal(0, 3)
    r = rng.uniform(0, 1)
    a = np.exp(-8.0 * np.log1p(np.exp(lam)) * r)
    assert 0.0 < a <= 1.0


# ---------------------------------------------------------------------------
# Serve-side page free list (PR 5): conservation under arbitrary sequences
# ---------------------------------------------------------------------------
@given(st.integers(1, 32), st.data())
def test_page_pool_conservation(num_pages, data):
    """Across arbitrary admit/evict/exhaustion sequences the scheduler's
    page free list never leaks or double-frees a page: every page id is
    tracked exactly once and ``available() + pages_in_tables() ==
    num_pages`` holds after every operation.  Misuse fails loudly."""
    from repro.serve.scheduler import PagePool

    pool = PagePool(num_pages)
    model = {}                          # slot -> page count (reference)
    for _ in range(data.draw(st.integers(1, 60), label="ops")):
        op = data.draw(st.sampled_from(["admit", "evict"]), label="op")
        if op == "admit":
            slot = data.draw(st.integers(0, 7), label="slot")
            want = data.draw(st.integers(1, num_pages + 2), label="pages")
            if slot in model or want > pool.available():
                # occupied slot / pool exhaustion: loud refusal, no change
                with pytest.raises(ValueError):
                    pool.alloc(slot, want)
            else:
                pages = pool.alloc(slot, want)
                assert len(pages) == len(set(pages)) == want
                model[slot] = want
        elif model:
            slot = data.draw(st.sampled_from(sorted(model)), label="victim")
            freed = pool.free(slot)
            assert len(freed) == model.pop(slot)
        else:
            with pytest.raises(KeyError):   # double free / never admitted
                pool.free(data.draw(st.integers(0, 7), label="ghost"))
        assert pool.available() + pool.pages_in_tables() == num_pages
        assert pool.pages_in_tables() == sum(model.values())
        assert pool.owner_slots() == set(model)
    # drain: every page returns to the free list exactly once
    for slot in sorted(model):
        pool.free(slot)
    assert pool.available() == num_pages and pool.pages_in_tables() == 0


# ---------------------------------------------------------------------------
# Refcounted radix prefix cache (PR 7): conservation generalizes — free +
# cached + in-use partition the pool, sum(refcounts) == table occupancy
# ---------------------------------------------------------------------------
@given(st.integers(4, 24), st.data())
def test_radix_page_pool_refcount_conservation(num_pages, data):
    """Interleaved admit(shared run + CoW)/register/free sequences over a
    tiny token alphabet (forcing prefix collisions) never break refcount
    conservation: every page is free, cached (refcount 0 but registered),
    or in use (refcount >= 1) — exactly one of the three — and the sum of
    refcounts equals total page-table occupancy.  The pool's internal
    ``_check`` re-asserts the full invariant (including trie <-> reverse
    map bijection) after every operation."""
    from repro.serve.scheduler import RadixPagePool

    ps = data.draw(st.integers(1, 3), label="page_size")
    pool = RadixPagePool(num_pages, ps)
    prompts = {}                        # slot -> prompt (reference model)

    def check():
        in_use = pool.in_use_pages()
        assert pool.available() + len(in_use) == num_pages
        assert sum(pool.refcount(p) for p in in_use) \
            == pool.pages_in_tables()

    for _ in range(data.draw(st.integers(1, 60), label="ops")):
        op = data.draw(st.sampled_from(["admit", "free", "register"]),
                       label="op")
        if op == "admit":
            slot = data.draw(st.integers(0, 5), label="slot")
            prompt = data.draw(
                st.lists(st.integers(0, 2), min_size=1, max_size=3 * ps),
                label="prompt")
            total = -(-len(prompt) // ps) + 1       # prompt + decode room
            shared, matched = pool.match(prompt)
            # mirror the scheduler's plan: keep >= 1 token to re-insert;
            # CoW every shared page the resume point writes into
            resume = min(matched, len(prompt) - 1)
            cow_idx = list(range(resume // ps, len(shared)))
            n_tail = total - len(shared)
            if slot in prompts or \
                    not pool.can_admit(shared, n_tail + len(cow_idx)):
                with pytest.raises(ValueError):
                    pool.admit(slot, shared, n_tail, cow_idx)
            else:
                pairs, restored = pool.admit(slot, shared, n_tail, cow_idx)
                assert len(pairs) == len(cow_idx)
                assert restored == []       # no host tier configured
                table = pool.table(slot)
                assert len(table) == len(set(table)) == total
                for p in table:
                    assert pool.refcount(p) >= 1
                # CoW produced private copies: the slot never maps a page
                # at a write index it shares with another owner
                for i in cow_idx:
                    assert pool.refcount(table[i]) == 1
                prompts[slot] = list(prompt)
        elif op == "free" and prompts:
            slot = data.draw(st.sampled_from(sorted(prompts)),
                             label="victim")
            freed = pool.free(slot)
            assert len(freed) == -(-len(prompts.pop(slot)) // ps) + 1
        elif op == "register" and prompts:
            slot = data.draw(st.sampled_from(sorted(prompts)),
                             label="registrant")
            pool.register(slot, prompts[slot])
        else:
            with pytest.raises(KeyError):
                pool.free(data.draw(st.integers(0, 5), label="ghost"))
        check()
    # drain: in-use pages leave through free; registered content stays
    # cached (still reclaimable), so availability returns to the full pool
    for slot in sorted(prompts):
        pool.free(slot)
    check()
    assert pool.available() == num_pages and pool.pages_in_tables() == 0


# ---------------------------------------------------------------------------
# Two-tier prefix cache (PR 9): the conservation invariant extends to the
# host spill tier — spilled and device-registered keys are disjoint, byte
# accounting is exact under the budget, and restore conserves pages
# ---------------------------------------------------------------------------
@given(st.integers(4, 16), st.integers(1, 12), st.data())
def test_two_tier_pool_spill_restore_conservation(num_pages, budget_pages,
                                                  data):
    """Arbitrary admit/register/free sequences over a RadixPagePool with a
    host spill tier (fake uniform-size spill blobs) never break the
    generalized invariant: device-cached and host-spilled keys stay
    disjoint (the pool's ``_check`` asserts it after every transaction),
    host byte accounting is exact and bounded by the budget, and a
    restore claims pages from the free list — page conservation holds
    through spill AND restore.  The admit mirror replicates the
    scheduler's ``_plan``: device match, host continuation, the final
    restored page excluded from re-registration when the resume point
    writes into it."""
    from repro.serve.scheduler import RadixPagePool

    ps = data.draw(st.integers(1, 3), label="page_size")
    blob_nbytes = 16                            # one fake array per page
    pool = RadixPagePool(num_pages, ps,
                         host_bytes=budget_pages * blob_nbytes)
    pool.set_spill_fn(lambda page: [np.zeros(blob_nbytes, np.int8)])
    prompts = {}                                # slot -> prompt (reference)

    def check():
        in_use = pool.in_use_pages()
        assert pool.available() + len(in_use) == num_pages
        assert sum(pool.refcount(p) for p in in_use) \
            == pool.pages_in_tables()
        # exact byte accounting: uniform blobs, so used == entries * size
        assert pool.host_used_bytes() \
            == pool.host_pages() * blob_nbytes <= pool.host_bytes

    for _ in range(data.draw(st.integers(1, 60), label="ops")):
        op = data.draw(st.sampled_from(["admit", "free", "register"]),
                       label="op")
        if op == "admit":
            slot = data.draw(st.integers(0, 5), label="slot")
            prompt = data.draw(
                st.lists(st.integers(0, 2), min_size=1, max_size=3 * ps),
                label="prompt")
            total = -(-len(prompt) // ps) + 1   # prompt + decode room
            shared, matched = pool.match(prompt)
            host_keys = pool.host_match(prompt, len(shared))
            resume = min((len(shared) + len(host_keys)) * ps,
                         len(prompt) - 1)
            cow_idx = list(range(resume // ps, len(shared)))
            n_host_reg = min(len(host_keys),
                             max(0, resume // ps - len(shared)))
            n_tail = total - len(shared) - len(host_keys)
            n_fresh = n_tail + len(cow_idx) + len(host_keys)
            if slot in prompts or not pool.can_admit(shared, n_fresh):
                with pytest.raises(ValueError):
                    pool.admit(slot, shared, n_tail, cow_idx,
                               host_keys=host_keys, n_host_reg=n_host_reg)
            else:
                pairs, restored = pool.admit(
                    slot, shared, n_tail, cow_idx,
                    host_keys=host_keys, n_host_reg=n_host_reg)
                assert len(pairs) == len(cow_idx)
                assert len(restored) == len(host_keys)
                # every restored key left the host tier in the transaction
                for key in host_keys:
                    assert key not in pool.spilled_keys()
                table = pool.table(slot)
                assert len(table) == len(set(table)) == total
                for p, ent in restored:
                    assert p in table and ent["nbytes"] == blob_nbytes
                prompts[slot] = list(prompt)
        elif op == "free" and prompts:
            slot = data.draw(st.sampled_from(sorted(prompts)),
                             label="victim")
            freed = pool.free(slot)
            assert len(freed) == -(-len(prompts.pop(slot)) // ps) + 1
        elif op == "register" and prompts:
            slot = data.draw(st.sampled_from(sorted(prompts)),
                             label="registrant")
            up_to = data.draw(
                st.one_of(st.none(),
                          st.integers(0, len(prompts[slot]))),
                label="up_to")
            pool.register(slot, prompts[slot], up_to=up_to)
            # a registered key supersedes its host copy: tiers disjoint
            # (the pool's _check also asserts this internally)
        else:
            with pytest.raises(KeyError):
                pool.free(data.draw(st.integers(0, 5), label="ghost"))
        check()
    # drain and reclaim everything: spills fill the host tier, the free
    # list returns to the full pool — no page leaked to either tier
    for slot in sorted(prompts):
        pool.free(slot)
    check()
    assert pool.available() == num_pages and pool.pages_in_tables() == 0
