"""Pix-Con kernel: shape/dtype sweep vs the pure-jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.pixcon.kernel import pixcon_gate_pallas
from repro.kernels.pixcon.ops import pixcon_gate
from repro.kernels.pixcon.ref import pixcon_gate_ref


def _mk(rng, B, T, P, F, H, dtype):
    x = jnp.asarray(rng.normal(0, 1, (B, T, P)), dtype)
    feats = jnp.asarray(rng.normal(0, 1, (B, P, F)), dtype)
    w1 = jnp.asarray(rng.normal(0, 0.5, (F, H)), jnp.float32)
    b1 = jnp.asarray(rng.normal(0, 0.1, (H,)), jnp.float32)
    w2 = jnp.asarray(rng.normal(0, 0.5, (H,)), jnp.float32)
    b2 = jnp.zeros((1,), jnp.float32)
    return x, feats, w1, b1, w2, b2


@pytest.mark.parametrize("B,T,P", [(1, 8, 16), (3, 33, 64), (8, 128, 64),
                                   (2, 200, 256), (5, 17, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sweep_matches_ref(rng, B, T, P, dtype):
    args = _mk(rng, B, T, P, 4, 32, dtype)
    got = pixcon_gate_pallas(*args, interpret=True)
    want = pixcon_gate_ref(*args[:4], args[4], args[5])
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("normalize", [True, False])
@pytest.mark.parametrize("temperature", [0.5, 1.0, 4.0])
def test_options(rng, normalize, temperature):
    args = _mk(rng, 2, 16, 64, 4, 16, jnp.float32)
    got = pixcon_gate_pallas(*args, normalize=normalize,
                             temperature=temperature, interpret=True)
    want = pixcon_gate_ref(*args[:4], args[4], args[5],
                           normalize=normalize, temperature=temperature)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_jitted_wrapper(rng):
    args = _mk(rng, 4, 30, 64, 4, 32, jnp.float32)
    got = pixcon_gate(*args)
    want = pixcon_gate_ref(*args[:4], args[4], args[5])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_blockspec_tiling_off_sizes(rng):
    """B/T not multiples of the block sizes exercise the grid edges."""
    args = _mk(rng, 9, 130, 64, 4, 32, jnp.float32)
    got = pixcon_gate_pallas(*args, block_b=4, block_t=64, interpret=True)
    want = pixcon_gate_ref(*args[:4], args[4], args[5])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
