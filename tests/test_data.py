"""Synthetic hydrology generator + input pipeline (the paper's I.P.)."""
import numpy as np

from repro.data import generate_all_watersheds, generate_watershed, make_training_windows
from repro.data.pipeline import InputPipeline, train_split, train_test_split
from repro.data.tokens import synthetic_token_batch
from repro.configs import get_config, smoke_variant


def test_watershed_shapes():
    ws = generate_watershed(0, num_days=200, grid=(8, 8))
    assert ws.precip.shape == (200, 64)
    assert ws.dist.shape == (64,)
    assert ws.discharge.shape == (200,)
    assert np.all(ws.precip >= 0)
    assert np.all(np.isfinite(ws.discharge))


def test_distance_prior_matters():
    """Near-stream pixels must contribute more to discharge than distant
    ones — the domain knowledge Pix-Con is supposed to recover."""
    ws = generate_watershed(1, num_days=1000)
    q = ws.discharge
    # correlation of each pixel's (short-lag) precip with discharge
    corr = []
    for p in range(ws.precip.shape[1]):
        x = ws.precip[:-1, p]
        c = np.corrcoef(x, q[1:])[0, 1]
        corr.append(c)
    corr = np.asarray(corr)
    near = corr[ws.dist <= np.median(ws.dist)].mean()
    far = corr[ws.dist > np.median(ws.dist)].mean()
    assert near > far, (near, far)


def test_discharge_responds_to_rain():
    ws = generate_watershed(2, num_days=600)
    heavy = ws.precip.mean(1) > np.quantile(ws.precip.mean(1), 0.9)
    # discharge within 3 days of heavy rain higher than dry-period discharge
    resp = np.zeros_like(ws.discharge, bool)
    for l in range(4):
        resp[l:] |= heavy[:len(heavy) - l]
    assert ws.discharge[resp].mean() > ws.discharge[~resp].mean()


def test_23_watersheds_differ():
    data = generate_all_watersheds(23, num_days=100)
    assert len(data) == 23
    means = [w.precip.mean() for w in data.values()]
    assert np.std(means) > 0.01          # climates differ


def test_windows_and_split():
    ws = generate_watershed(0, num_days=120)
    w = make_training_windows(ws, window=30)
    assert w.precip.shape == (90, 30, 64)
    assert w.target_day.shape == (90, 64)
    tr, te = train_test_split(w, 0.25)
    assert len(tr["discharge"]) == 67 and len(te["discharge"]) == 23
    # target_day is the day being predicted, not part of the window
    # (both are scaled by the same normalizer -> proportional)
    c = ws.precip[30].sum() / (w.target_day[0].sum() + 1e-9)
    np.testing.assert_allclose(w.target_day[0] * c, ws.precip[30], rtol=1e-4)


def test_train_split_excludes_heldout_tail():
    """Windows fed to training and the test pack from train_test_split must
    partition the data — the pipeline never sees the held-out tail."""
    ws = generate_watershed(0, num_days=120)
    w = make_training_windows(ws, window=30)
    tw = train_split(w, 0.25)
    tr, te = train_test_split(w, 0.25)
    assert len(tw.discharge) == len(tr["discharge"])
    np.testing.assert_array_equal(tw.precip, tr["precip"])
    # and the first held-out row is NOT in the training windows
    assert len(tw.discharge) + len(te["discharge"]) == len(w.discharge)
    np.testing.assert_array_equal(
        te["precip"][0], w.precip[len(tw.discharge)])
    # normalization stats come from the full windows (shared)
    assert tw.q_mean == w.q_mean and tw.q_std == w.q_std


def test_pipeline_sharding_partitions_watersheds():
    data = generate_all_watersheds(7, num_days=80)
    windows = [make_training_windows(w) for w in data.values()]
    ip = InputPipeline(windows, batch_size=8)
    shards = [ip.shard(i, 3) for i in range(3)]
    ids = sorted(w.watershed_id for s in shards for w in s.windows)
    assert ids == list(range(7))          # exact cover, no duplicates


def test_stacked_batches_align():
    data = generate_all_watersheds(3, num_days=80)
    windows = [make_training_windows(w) for w in data.values()]
    ip = InputPipeline(windows, batch_size=8)
    b = next(iter(ip.stacked_batches(0)))
    assert b["precip"].shape[:2] == (3, 8)
    assert b["discharge"].shape == (3, 8)


def test_token_batches_learnable_structure():
    cfg = smoke_variant(get_config("olmo-1b"))
    b = synthetic_token_batch(cfg, 4, 64, seed=1)
    assert b["tokens"].shape == (4, 64)
    # targets are next-token shifted
    b2 = synthetic_token_batch(cfg, 4, 64, seed=1)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])  # deterministic
