"""Dom-ST core: Pix-Con, partitioner, spatial/temporal blocks, training."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_config
from repro.core import domst
from repro.core.partitioner import partition_pixels, static_partition
from repro.core.pixcon import contribution_weights, pixcon_params
from repro.data import generate_watershed, make_training_windows
from repro.data.pipeline import train_test_split
from repro.distributed.sharding import ParamFactory
from repro.optim import make_optimizer


def _batch(rng, n=8, T=30, P=64):
    return {
        "precip": jnp.asarray(rng.normal(0, 1, (n, T, P)).astype("float32")),
        "target_day": jnp.asarray(rng.normal(0, 1, (n, P)).astype("float32")),
        "dist": jnp.asarray(rng.uniform(0, 1, (n, P)).astype("float32")),
        "discharge": jnp.asarray(rng.normal(0, 1, n).astype("float32")),
    }


def test_pixcon_weights_in_range(rng, key):
    cfg = get_config("domst")
    pc = cfg.domst.pixcon
    params = pixcon_params(ParamFactory(key), pc)
    b = _batch(rng)
    w = contribution_weights(params, pc, b["precip"], b["dist"],
                             b["target_day"])
    assert w.shape == (8, 64)
    assert bool(jnp.all(w >= 0))
    # normalized: mean weight == 1 (mass preserved)
    np.testing.assert_allclose(np.asarray(jnp.mean(w, -1)), 1.0, rtol=1e-5)


def test_partitioner_is_a_permutation(rng):
    x = jnp.asarray(rng.normal(0, 1, (4, 30, 64)).astype("float32"))
    w = jnp.asarray(rng.uniform(0, 1, (4, 64)).astype("float32"))
    parts, order = partition_pixels(x, w, 4)
    assert parts.shape == (4, 4, 30, 16)
    # every pixel appears exactly once
    assert np.all(np.sort(np.asarray(order), axis=-1)
                  == np.arange(64)[None, :])
    # partition 0 holds the highest-contribution pixels
    w_np = np.asarray(w)
    got_first = np.asarray(order)[:, :16]
    for b in range(4):
        top16 = np.argsort(-w_np[b])[:16]
        assert set(got_first[b].tolist()) == set(top16.tolist())
    # values preserved: sum over pixels invariant
    np.testing.assert_allclose(np.asarray(parts).sum((1, 3)),
                               np.asarray(x).sum(-1), rtol=1e-4, atol=1e-4)


def test_static_partition_shape(rng):
    x = jnp.asarray(rng.normal(0, 1, (2, 30, 64)).astype("float32"))
    assert static_partition(x, 4).shape == (2, 4, 30, 16)


def test_forward_shapes_all_variants(rng, key):
    b = _batch(rng)
    for name in ("domst", "domst-singlehead", "domst-singlehead-p"):
        cfg = get_config(name)
        params = domst.init(cfg, key)
        q = domst.forward(params, cfg, b)
        assert q.shape == (8,)
        assert bool(jnp.all(jnp.isfinite(q)))


def test_training_improves_nse(key):
    cfg = get_config("domst")
    ws = generate_watershed(3, num_days=300)
    w = make_training_windows(ws)
    tr, te = train_test_split(w)
    params = domst.init(cfg, key)
    te_j = {k: jnp.asarray(v) for k, v in te.items()}
    nse0 = float(domst.evaluate(params, cfg, te_j)["nse"])
    tc = TrainConfig(learning_rate=3e-3, total_steps=200, warmup_steps=10)
    step = domst.make_train_step(cfg, tc)
    opt = make_optimizer(tc)[0](params)
    rng = np.random.default_rng(0)
    n = len(tr["discharge"])
    for it in range(60):
        sl = rng.integers(0, n, 64)
        b = {k: jnp.asarray(v[sl]) for k, v in tr.items()}
        params, opt, m = step(params, opt, b)
    nse1 = float(domst.evaluate(params, cfg, te_j)["nse"])
    assert nse1 > nse0 and nse1 > 0.2, (nse0, nse1)


def test_stacked_step_isolates_watersheds(rng, key):
    """Replica w's params must depend only on watershed w's data."""
    cfg = get_config("domst")
    tc = TrainConfig(learning_rate=1e-3, total_steps=10, warmup_steps=1)
    params = domst.init_stacked(cfg, key, 2)
    opt = jax.vmap(make_optimizer(tc)[0])(params)
    step = domst.make_stacked_train_step(cfg, tc)
    b1 = {k: jnp.stack([v, v]) for k, v in _batch(rng).items()}
    # perturb only watershed 1's data
    b2 = jax.tree.map(lambda x: x, b1)
    b2 = {k: v.at[1].add(1.0) for k, v in b1.items()}
    p1, _, _ = step(params, opt, b1)
    p2, _, _ = step(params, opt, b2)
    d0 = sum(float(jnp.sum(jnp.abs(a[0] - b[0])))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    d1 = sum(float(jnp.sum(jnp.abs(a[1] - b[1])))
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d0 == 0.0 and d1 > 0.0
