"""Engine-driven sharded inference (repro/serve/): prefill+decode parity
across every decodable arch, rule-table shardings of the InferenceState on
a forced multi-device mesh, continuous-batching invariants (slot reuse,
ragged prompts, arrival-order determinism), and the train -> ckpt -> serve
hand-off."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (
    ASSIGNED_ARCHS, TrainConfig, get_config, smoke_variant,
)
from repro.core import domst
from repro.data.pipeline import make_domst_windows, stacked_test_batch
from repro.distributed.sharding import (
    cache_needs_seq_shard, make_rules, tree_shardings,
)
from repro.models import transformer as T
from repro.models.layers import unembed
from repro.serve import InferenceEngine, Request, Scheduler
from repro.train import Engine

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
DECODE_ARCHS = [a for a in ASSIGNED_ARCHS if get_config(a).supports_decode()]

PROMPT, GEN = 8, 4


def _ample_moe(cfg):
    """Capacity large enough that routing never drops tokens (else the
    full-sequence pass and the one-token decode pass drop differently)."""
    import dataclasses
    if cfg.moe is not None:
        return cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                   capacity_factor=8.0))
    return cfg


def _requests(cfg, lens, gen=GEN, seed=0):
    rng = np.random.default_rng(seed)
    reqs = []
    for i, n in enumerate(lens):
        extras = {}
        if cfg.family == "vlm":
            extras["patches"] = rng.normal(
                0, 1, (cfg.num_patches, cfg.frontend_dim)).astype(np.float32)
        reqs.append(Request(
            rid=i, max_new=gen, extras=extras,
            prompt=rng.integers(0, cfg.vocab_size, n).astype(np.int32)))
    return reqs


def _serve(cfg, reqs, *, slots, eos=None, mesh=None, max_len=None,
           sched_kw=None, **kw):
    eng = InferenceEngine(cfg, slots=slots, mesh=mesh, dtype=jnp.float32,
                          max_len=max_len or (PROMPT + GEN
                                              + (cfg.num_patches or 0)),
                          **kw)
    state = eng.init_state(T.init(cfg, jax.random.key(0)))
    sched = Scheduler(eng, state, eos_id=eos, **(sched_kw or {}))
    return sched.run(reqs), sched


# ---------------------------------------------------------------------------
# Prefill + decode parity: greedy tokens off the incremental cache path must
# bit-match a teacher-forced full-sequence forward argmax, for every arch
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_parity(arch):
    cfg = _ample_moe(smoke_variant(get_config(arch)))
    reqs = _requests(cfg, [PROMPT, PROMPT])
    out, _ = _serve(cfg, reqs, slots=2)
    # reference: full-sequence forward over prompt + generated (the params
    # in the engine state were donated — re-init the identical tree)
    params = T.init(cfg, jax.random.key(0))
    for r in reqs:
        full = np.concatenate([r.prompt, np.asarray(out[r.rid], np.int32)])
        inputs = {"tokens": jnp.asarray(full[None, :-1])}
        for k, v in r.extras.items():
            inputs[k] = jnp.asarray(v[None])
        x, _ = T.forward(params, cfg, inputs, dtype=jnp.float32)
        logits = unembed(params["embed"], x, tie=cfg.tie_embeddings,
                         cap=cfg.logit_softcap, real_vocab=cfg.vocab_size)
        start = (cfg.num_patches or 0) + len(r.prompt) - 1
        want = np.asarray(jnp.argmax(logits[0, start:start + GEN], -1))
        assert want.tolist() == out[r.rid], arch


# ---------------------------------------------------------------------------
# Continuous batching invariants
# ---------------------------------------------------------------------------
def test_ragged_prompts_match_solo_runs():
    """Requests with ragged prompt lengths served in ONE batch produce the
    same tokens as each request served alone."""
    cfg = smoke_variant(get_config("olmo-1b"))
    lens = [5, 8, 6, 7]
    batched, _ = _serve(cfg, _requests(cfg, lens), slots=4)
    for i, n in enumerate(lens):
        solo, _ = _serve(cfg, [_requests(cfg, lens)[i]], slots=1)
        assert solo[i] == batched[i], (i, n)


def test_arrival_order_determinism():
    """Per-request output is a function of the prompt alone: any queue
    order / slot assignment / co-batching yields identical tokens."""
    cfg = smoke_variant(get_config("olmo-1b"))
    lens = [8, 5, 7, 6]
    a, _ = _serve(cfg, _requests(cfg, lens), slots=2)
    shuffled = _requests(cfg, lens)
    shuffled = [shuffled[i] for i in (3, 1, 0, 2)]
    b, _ = _serve(cfg, shuffled, slots=2)
    assert a == b


def test_eos_eviction_reuses_slot():
    """A request hitting EOS is evicted immediately, its slot is reused by
    a pending request, and every stream equals its solo run truncated at
    the first EOS."""
    cfg = smoke_variant(get_config("olmo-1b"))
    lens = [8, 7, 6]
    # probe: pick request 0's 2nd greedy token as the EOS id
    probe, _ = _serve(cfg, _requests(cfg, lens), slots=2)
    eos = probe[0][1]

    def truncate(toks):
        return toks[:toks.index(eos) + 1] if eos in toks else toks

    out, sched = _serve(cfg, _requests(cfg, lens), slots=2, eos=eos)
    for rid in (0, 1, 2):
        assert out[rid] == truncate(probe[rid]), rid
    assert out[0][-1] == eos and len(out[0]) < GEN
    # request 2 was pending behind 2 slots; the early eviction freed one
    reused = [h for h in sched.slot_history.values() if len(h) > 1]
    assert reused and any(2 in h for h in reused), sched.slot_history


# ---------------------------------------------------------------------------
# Paged KV cache + chunked prefill: the contiguous slot-major layout is the
# parity baseline — greedy tokens must be identical through the page pool,
# whole-prompt or chunk by chunk, under slot reuse and co-batched decode
# ---------------------------------------------------------------------------
def test_paged_whole_prompt_matches_contiguous():
    cfg = smoke_variant(get_config("olmo-1b"))
    lens = [8, 5, 7, 6]
    ref, _ = _serve(cfg, _requests(cfg, lens), slots=2)
    got, _ = _serve(cfg, _requests(cfg, lens), slots=2, paged=True,
                    page_size=4)
    assert got == ref


@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "mamba2-130m"])
def test_chunked_prefill_matches_contiguous_recurrent(arch):
    """Chunked prefill replays recurrent/SSM state chunk by chunk from the
    slot's row (reset on reuse) — tokens must match the whole-prompt path,
    including the ragged remainder chunk."""
    cfg = _ample_moe(smoke_variant(get_config(arch)))
    lens = [8, 5, 7, 6]                     # 8 = 2 full chunks + remainder 2
    ref, _ = _serve(cfg, _requests(cfg, lens), slots=2)
    got, sched = _serve(cfg, _requests(cfg, lens), slots=2, paged=True,
                        page_size=4, prefill_chunk=3)
    assert got == ref
    assert sched.stats["prefill_chunks"] >= 2 * len(lens)


def test_paged_pool_decouples_slots_from_max_len():
    """A pool sized to live tokens (num_pages << slots * pages_per_slot)
    serves a generously provisioned engine with identical tokens and a
    fraction of the KV memory."""
    cfg = smoke_variant(get_config("olmo-1b"))
    lens = [8, 5, 7, 6]
    ref, ref_sched = _serve(cfg, _requests(cfg, lens), slots=2, max_len=48)
    live_pages = 2 * (-(-(PROMPT + GEN) // 4))          # 2 slots * ceil(12/4)
    got, sched = _serve(cfg, _requests(cfg, lens), slots=2, max_len=48,
                        paged=True, page_size=4, num_pages=live_pages)
    assert got == ref
    bytes_of = lambda s: sum(x.nbytes for x in jax.tree.leaves(s.state.cache))
    assert bytes_of(sched) < bytes_of(ref_sched) / 2, \
        (bytes_of(sched), bytes_of(ref_sched))


def test_page_exhaustion_defers_admission():
    """With pages for only one request at a time, the second request waits
    for the first eviction instead of corrupting the pool; an unservable
    request fails loudly."""
    cfg = smoke_variant(get_config("olmo-1b"))
    lens = [8, 7, 6]
    ref, _ = _serve(cfg, _requests(cfg, lens), slots=2)
    pages_one = -(-(PROMPT + GEN) // 4)                 # exactly one request
    got, sched = _serve(cfg, _requests(cfg, lens), slots=2, paged=True,
                        page_size=4, num_pages=pages_one)
    assert got == ref
    assert sched.stats["decode_steps"] >= 3 * (GEN - 1)  # served serially
    # the waiting isn't silent: every deferred admission cycle is counted,
    # and the worst single request's wait is reported
    assert sched.stats["deferred_admissions"] > 0
    assert sched.stats["max_defer_cycles"] > 0
    assert sched.lifetime_stats["max_defer_cycles"] \
        == sched.stats["max_defer_cycles"]
    with pytest.raises(ValueError, match="pages"):
        _serve(cfg, _requests(cfg, [PROMPT]), slots=1, paged=True,
               page_size=4, num_pages=1)


def test_chunked_admission_does_not_perturb_inflight_streams():
    """The adversarial arrival the admission queue exists for: a long
    prompt is chunk-prefilled into a freed slot WHILE a victim request
    decodes — every stream must match the contiguous whole-prompt run."""
    cfg = smoke_variant(get_config("olmo-1b"))
    rng = np.random.default_rng(3)
    mk = lambda rid, n, g: Request(
        rid=rid, max_new=g,
        prompt=rng.integers(0, cfg.vocab_size, n).astype(np.int32))
    queue = lambda: [mk(0, 4, 10),          # victim: decodes throughout
                     mk(1, 4, 2),           # frees its slot quickly
                     mk(2, 16, 3)]          # long prompt, admitted mid-stream
    rng = np.random.default_rng(3)
    ref, _ = _serve(cfg, queue(), slots=2, max_len=32)
    rng = np.random.default_rng(3)
    got, sched = _serve(cfg, queue(), slots=2, max_len=32, paged=True,
                        page_size=4, prefill_chunk=4)
    assert got == ref
    assert sched.stats["prefill_chunks"] >= 4   # the long prompt chunked
    # the victim stream (1 prefill + 9 decode tokens) ran to completion
    # fused with the other slots — its decodes bracket the admission
    assert sched.stats["decode_steps"] >= 9


# ---------------------------------------------------------------------------
# Refcounted prefix cache + page-aware preemption (PR 7)
# ---------------------------------------------------------------------------
def _shared_prefix_requests(cfg, shared, tails, gen=GEN, seed=0):
    """Requests whose prompts share their first ``shared`` tokens."""
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, cfg.vocab_size, shared).astype(np.int32)
    return [Request(rid=i, max_new=gen, prompt=np.concatenate(
                [pre, rng.integers(0, cfg.vocab_size, t).astype(np.int32)]))
            for i, t in enumerate(tails)]


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "gemma2-2b",
                                  "recurrentgemma-2b"])
def test_prefix_cache_hit_matches_cold_prefill(arch):
    """The PR's acceptance bar: greedy streams served off prefix-cache
    hits are bit-identical to the cold-prefill run across attention-only,
    local/global, and recurrent-hybrid archs.  On the hybrid, the resume
    is boundary-capped and replays the registered recurrent snapshot, so
    generation genuinely starts from the divergence point."""
    cfg = _ample_moe(smoke_variant(get_config(arch)))
    mk = lambda: _shared_prefix_requests(cfg, 24, [4, 4, 6])
    ref, _ = _serve(cfg, mk(), slots=2, max_len=48, paged=True,
                    page_size=8, prefill_chunk=6)
    got, sched = _serve(cfg, mk(), slots=2, max_len=48, paged=True,
                        page_size=8, prefill_chunk=6,
                        sched_kw={"prefix_cache": True})
    assert got == ref, arch
    # at least the request admitted after the first registration hit the
    # cache, skipping the full shared run (3 pages = 24 tokens)
    assert sched.stats["prefix_hits"] >= 1
    assert sched.stats["prefix_hit_tokens"] >= 24


def test_prefix_cache_exact_match_copy_on_write():
    """A prompt fully covered by cached pages still re-inserts its final
    token for the first-token logits — that write must land in a private
    copy-on-write page, never in the shared original, and the stream must
    still bit-match the cold run."""
    cfg = smoke_variant(get_config("qwen2-1.5b"))
    eng = InferenceEngine(cfg, slots=2, max_len=32, dtype=jnp.float32,
                          paged=True, page_size=8)
    state = eng.init_state(T.init(cfg, jax.random.key(0)))
    sched = Scheduler(eng, state, prefix_cache=True)
    rng = np.random.default_rng(5)
    p = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)  # 2 full pages
    cold = sched.run([Request(rid=0, prompt=p.copy(), max_new=GEN)])
    warm = sched.run([Request(rid=1, prompt=p.copy(), max_new=GEN)])
    assert warm[1] == cold[0]
    assert sched.stats["cow_pages"] == 1        # exactly the written page
    assert sched.stats["prefix_hit_tokens"] == len(p) - 1
    # lifetime view accumulated both runs' lookups
    assert sched.lifetime_stats["prefix_lookups"] == 2


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "recurrentgemma-2b"])
def test_preemption_matches_deferred_run(arch):
    """Under page pressure the preempting scheduler swaps the youngest
    active slot to host and restores it later; every stream must
    bit-match the defer-only baseline (which simply waits), including the
    recurrent-hybrid arch whose slot state travels in the swap blob."""
    cfg = _ample_moe(smoke_variant(get_config(arch)))
    mk = lambda: [Request(rid=i, max_new=4 + 2 * i,
                          prompt=np.random.default_rng(7 + i).integers(
                              0, cfg.vocab_size, 10 + i).astype(np.int32))
                  for i in range(3)]
    ref, base = _serve(cfg, mk(), slots=2, max_len=24, paged=True,
                       page_size=8, num_pages=4)
    got, sched = _serve(cfg, mk(), slots=2, max_len=24, paged=True,
                        page_size=8, num_pages=4,
                        sched_kw={"preempt": True})
    assert got == ref, arch
    assert base.stats["deferred_admissions"] > 0    # baseline had to wait
    assert sched.stats["preemptions"] >= 1
    assert sched.stats["restores"] == sched.stats["preemptions"]


def test_restore_head_not_starved_by_small_request_flood():
    """Fairness regression: a preempted large request parked on the
    restore queue must not wait behind an unbounded stream of small
    admissions.  The scheduler reserves the restore head's page need, so
    once enough pages free up the restore goes FIRST — pre-fix, every
    small admission grabbed the pages the head was waiting for and the
    large request restored dead last."""
    cfg = smoke_variant(get_config("olmo-1b"))
    rng = np.random.default_rng(9)
    big = lambda: Request(rid=0, max_new=12, prompt=rng.integers(
        0, cfg.vocab_size, 12).astype(np.int32))       # 24 tokens = 6 pages
    smalls = lambda: [Request(
        rid=i, max_new=4 + i % 2, prompt=rng.integers(
            0, cfg.vocab_size, 4 - i % 2).astype(np.int32))
        for i in range(1, 7)]                          # 2 pages each
    rng = np.random.default_rng(9)
    ref, _ = _serve(cfg, [big()] + smalls(), slots=2, max_len=24,
                    paged=True, page_size=4, num_pages=12)  # no pressure
    rng = np.random.default_rng(9)
    got, sched = _serve(cfg, [big()] + smalls(), slots=2, max_len=24,
                        paged=True, page_size=4, num_pages=6,
                        sched_kw={"preempt": True})
    assert got == ref                                  # still lossless
    assert sched.stats["preemptions"] >= 1             # big was swapped out
    order = sched.admission_order
    # the big request's FIRST restore must beat the later smalls into a
    # slot: pre-fix it trailed the whole flood ([0, 1..6, 0])
    assert order.index(0, 1) < order.index(3), order
    # the head's wait is visible, not silent
    assert sched.stats["deferred_admissions"] > 0


def test_preempt_gain_ignores_pages_pinned_by_shared_owners():
    """Preemption-accounting regression: feasibility must count only
    pages whose refcount actually drops to 0 when their active owners
    are swapped out.  Pre-fix the bound summed victim page tables, so
    pages shared with a mid-admission slot (pinned, non-preemptable)
    were double-counted and the scheduler preempted a victim, freed
    almost nothing, and deferred anyway — a wasted swap."""
    cfg = smoke_variant(get_config("qwen2-1.5b"))
    rng = np.random.default_rng(11)
    base = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    mk = lambda: [
        # B: registers its 4 prompt pages, then decodes (5 pages)
        Request(rid=0, max_new=4, prompt=base.copy()),
        # A: shares B's full prompt, resumes at 16 -> chunked admission
        # that PINS the 4 shared pages while not yet active (7 pages)
        Request(rid=1, max_new=4, prompt=np.concatenate(
            [base, rng.integers(0, cfg.vocab_size, 8).astype(np.int32)])),
        # C: distinct prompt, needs 3 fresh pages the pool can't supply
        Request(rid=2, max_new=4, prompt=rng.integers(
            0, cfg.vocab_size, 8).astype(np.int32))]
    rng = np.random.default_rng(11)
    base = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    ref, _ = _serve(cfg, mk(), slots=3, max_len=28, paged=True,
                    page_size=4, num_pages=9,
                    sched_kw={"prefix_cache": True})
    rng = np.random.default_rng(11)
    base = rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
    got, sched = _serve(cfg, mk(), slots=3, max_len=28, paged=True,
                        page_size=4, num_pages=9,
                        sched_kw={"prefix_cache": True, "preempt": True})
    assert got == ref
    # pre-fix: TWO preemptions (a wasted no-op swap of B while A pinned
    # B's shared pages, then the real one); post-fix only the real one
    assert sched.stats["preemptions"] == 1, sched.stats
    assert sched.stats["restores"] == 1


def test_prefix_cache_and_preempt_require_paged():
    cfg = smoke_variant(get_config("olmo-1b"))
    eng = InferenceEngine(cfg, slots=2, max_len=16, dtype=jnp.float32)
    state = eng.init_state(T.init(cfg, jax.random.key(0)))
    with pytest.raises(ValueError, match="paged"):
        Scheduler(eng, state, prefix_cache=True)
    with pytest.raises(ValueError, match="paged"):
        Scheduler(eng, state, preempt=True)
    peng = InferenceEngine(cfg, slots=2, max_len=16, dtype=jnp.float32,
                           paged=True, page_size=4)
    pstate = peng.init_state(T.init(cfg, jax.random.key(0)))
    with pytest.raises(ValueError, match="prefix_cache"):
        Scheduler(peng, pstate, host_cache_bytes=1 << 20)


# ---------------------------------------------------------------------------
# Two-tier host spill cache (PR 9): pages evicted from the device pool
# spill their KV (and recurrent snapshots) to host memory and swap back in
# on a later radix match instead of re-prefilling
# ---------------------------------------------------------------------------
def _family_requests(cfg, families, per_family, prefix_len, tail_len,
                     gen=GEN, seed=0):
    """Alternating shared-prefix families: request i uses family
    ``i % families``.  On a pool that only fits one request, each
    admission reclaims the previous family's cached pages — the forced
    spill pattern the host tier exists for."""
    rng = np.random.default_rng(seed)
    pres = [rng.integers(0, cfg.vocab_size, prefix_len).astype(np.int32)
            for _ in range(families)]
    return [Request(rid=i, max_new=gen, prompt=np.concatenate(
                [pres[i % families],
                 rng.integers(0, cfg.vocab_size, tail_len).astype(np.int32)]))
            for i in range(families * per_family)]


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "gemma2-2b",
                                  "recurrentgemma-2b"])
def test_host_tier_hit_matches_cold_and_device_hit(arch):
    """The PR's acceptance bar: greedy streams are bit-identical cold vs
    device-hit vs host-hit across attention-only, local/global and
    recurrent-hybrid archs.  The tight pool forces every admission to
    reclaim the other family's cached pages, so without a host tier the
    prefix cache contributes nothing; with one, the spilled pages (and,
    on the hybrid, the boundary snapshot) come back as real hits."""
    cfg = _ample_moe(smoke_variant(get_config(arch)))
    mk = lambda: _family_requests(cfg, 2, 2, 16, 8)
    kw = dict(slots=1, max_len=28, paged=True, page_size=8, prefill_chunk=6)
    cold, _ = _serve(cfg, mk(), num_pages=4, **kw)
    dev, dsched = _serve(cfg, mk(), num_pages=16, **kw,
                         sched_kw={"prefix_cache": True})
    host, hsched = _serve(cfg, mk(), num_pages=4, **kw,
                          sched_kw={"prefix_cache": True,
                                    "host_cache_bytes": 64 << 20})
    assert dev == cold, arch
    assert host == cold, arch
    # ample pool: hits stay device-side, the host tier is never engaged
    assert dsched.stats["prefix_hits"] >= 2
    assert dsched.stats["host_hits"] == 0
    # tight pool: the 16-token family prefix (2 pages) spills on every
    # cross-family admission and restores for the family's second request
    assert hsched.stats["host_hits"] >= 2
    assert hsched.stats["host_restored_pages"] >= 4
    assert hsched.stats["host_spilled_pages"] >= 4
    assert hsched.stats["prefix_hit_tokens"] >= 32


def test_inflight_registration_matches_no_cache_run():
    """In-flight gap closure: a request admitted WHILE a long prompt is
    still chunk-prefilling must match the prefiller's already-completed
    pages (refcount bump on live-slot pages).  Pre-fix, registration
    happened only at prefill completion — r0 finishes long after r2 is
    admitted, so the hit below could not exist."""
    cfg = smoke_variant(get_config("olmo-1b"))

    def mk():
        rng = np.random.default_rng(13)
        base = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
        return [
            # r0: 32-token prompt, chunk-prefills for 8 scheduler cycles
            Request(rid=0, max_new=4, prompt=base),
            # r1: finishes fast, freeing its slot while r0 still prefills
            Request(rid=1, max_new=3, prompt=rng.integers(
                0, cfg.vocab_size, 4).astype(np.int32)),
            # r2: shares r0's first 16 tokens, admitted into r1's slot
            Request(rid=2, max_new=4, prompt=np.concatenate(
                [base[:16],
                 rng.integers(0, cfg.vocab_size, 4).astype(np.int32)]))]

    kw = dict(slots=2, max_len=36, paged=True, page_size=4, prefill_chunk=4,
              num_pages=24)
    ref, _ = _serve(cfg, mk(), **kw)
    got, sched = _serve(cfg, mk(), **kw, sched_kw={"prefix_cache": True})
    assert got == ref
    # by the cycle r1's slot frees, r0 has registered >= 2 complete pages
    assert sched.stats["prefix_hits"] >= 1
    assert sched.stats["prefix_hit_tokens"] >= 8


def test_host_tier_lifetime_stats_fold_across_runs():
    """A second forced-spill batch through the SAME scheduler: the host
    counters folded into ``lifetime_stats`` must accumulate across runs
    (sum semantics) while max-type keys fold with max — and the per-run
    ``stats`` must describe only their own batch."""
    cfg = smoke_variant(get_config("qwen2-1.5b"))
    eng = InferenceEngine(cfg, slots=1, max_len=28, dtype=jnp.float32,
                          paged=True, page_size=8, num_pages=4)
    sched = Scheduler(eng, eng.init_state(T.init(cfg, jax.random.key(0))),
                      prefix_cache=True, host_cache_bytes=64 << 20)
    sched.run(_family_requests(cfg, 2, 2, 16, 8))
    first = dict(sched.stats)
    assert first["host_hits"] >= 2
    assert first["host_spilled_pages"] > 0
    # fresh families: run 2 forces its own spill/restore cycle
    sched.run(_family_requests(cfg, 2, 2, 16, 8, seed=1))
    second = dict(sched.stats)
    assert second["host_hits"] >= 2
    for k in ("host_hits", "host_hit_tokens", "host_restored_pages",
              "host_spilled_pages", "host_evicted_pages",
              "prefix_hits", "prefix_hit_tokens"):
        assert sched.lifetime_stats[k] == first[k] + second[k], k
    assert sched.lifetime_stats["max_defer_cycles"] == \
        max(first["max_defer_cycles"], second["max_defer_cycles"])


# ---------------------------------------------------------------------------
# Scheduler under adversarial arrival patterns
# ---------------------------------------------------------------------------
def test_admit_while_full_queues_and_reuses_slots():
    """More pending requests than slots: admission waits for evictions,
    every slot is reused, no slot serves two requests at once, and each
    stream matches its ample-slots run."""
    cfg = smoke_variant(get_config("olmo-1b"))
    lens = [8, 5, 7, 6, 8, 5]
    ref, _ = _serve(cfg, _requests(cfg, lens), slots=6)
    got, sched = _serve(cfg, _requests(cfg, lens), slots=2)
    assert got == ref
    served = sorted(r for h in sched.slot_history.values() for r in h)
    assert served == list(range(len(lens)))            # each rid exactly once
    assert all(len(h) >= 2 for h in sched.slot_history.values())


def test_eos_on_same_step_as_budget_eviction():
    """A request whose EOS lands exactly on its max_new-th token is evicted
    ONCE (EOS and budget agree), the stream is not truncated early, and the
    freed slot still serves the waiting request."""
    cfg = smoke_variant(get_config("olmo-1b"))
    lens = [8, 7, 6]
    probe, _ = _serve(cfg, _requests(cfg, lens), slots=2)
    eos = probe[0][GEN - 1]                 # request 0's FINAL budget token
    # avoid accidental early EOS in other streams making the test vacuous
    assume_clean = all(eos not in p[:GEN - 1] for p in probe.values())
    out, sched = _serve(cfg, _requests(cfg, lens), slots=2, eos=eos)
    assert len(out[0]) == GEN and out[0] == probe[0]
    if assume_clean:
        for rid in (1, 2):
            assert out[rid] == probe[rid], rid
    served = sorted(r for h in sched.slot_history.values() for r in h)
    assert served == [0, 1, 2]              # single admission per request
    assert 2 in sum(sched.slot_history.values(), [])   # pending req 2 served


def test_scheduler_stats_reset_between_runs():
    """A second batch through the SAME scheduler must report its own
    throughput/stall numbers: per-run ``stats`` reset when run() starts
    (the regression: decode_s / max_decode_gap_s accumulated forever, so
    a second batch inherited the first's worst stall and token counts),
    while ``lifetime_stats`` keeps the cross-run totals."""
    cfg = smoke_variant(get_config("olmo-1b"))
    eng = InferenceEngine(cfg, slots=2, dtype=jnp.float32,
                          max_len=PROMPT + GEN, paged=True, page_size=4)
    sched = Scheduler(eng, eng.init_state(T.init(cfg, jax.random.key(0))))
    sched.run(_requests(cfg, [8, 5, 7, 6]))
    first = dict(sched.stats)
    assert first["decode_steps"] > 0
    # poison the gap stat to prove the reset (a stall from batch 1 must
    # never be reported as batch 2's)
    sched.stats["max_decode_gap_s"] = 123.0
    sched.run(_requests(cfg, [6, 6]))
    second = dict(sched.stats)
    assert second["decode_steps"] == GEN - 1        # one 2-slot batch
    assert second["decode_tokens"] == 2 * (GEN - 1)
    assert second["max_decode_gap_s"] < 123.0
    life = sched.lifetime_stats
    assert life["decode_steps"] == first["decode_steps"] + GEN - 1
    assert life["decode_tokens"] == \
        first["decode_tokens"] + second["decode_tokens"]
    assert life["max_decode_gap_s"] == max(first["max_decode_gap_s"],
                                           second["max_decode_gap_s"])
    # the page free list survived both runs intact
    assert sched._pages.available() == eng.num_pages
    assert sched._pages.pages_in_tables() == 0


def test_zero_length_generation_rejected():
    """max_new=0 can't be served (prefill itself emits one token): the
    scheduler must refuse loudly, for whole-prompt and chunked admission
    alike, before serving ANY of the queue — even when the bad request
    sits behind valid ones whose tokens would otherwise be discarded."""
    cfg = smoke_variant(get_config("olmo-1b"))
    reqs = _requests(cfg, [PROMPT, PROMPT])
    reqs[1].max_new = 0                     # behind a valid request
    with pytest.raises(ValueError, match="max_new"):
        _serve(cfg, reqs, slots=1)
    assert reqs[0].generated == []          # nothing served then thrown away
    reqs = _requests(cfg, [PROMPT])
    reqs[0].max_new = 0
    with pytest.raises(ValueError, match="max_new"):
        _serve(cfg, reqs, slots=1, paged=True, page_size=4, prefill_chunk=4)


# ---------------------------------------------------------------------------
# Rule-table shardings of the InferenceState on a real multi-device mesh
# ---------------------------------------------------------------------------
needs8 = pytest.mark.skipif(len(jax.devices()) < 8,
                            reason="needs 8 host devices (CI sets XLA_FLAGS)")


def _leaf_shardings(tree):
    return jax.tree.leaves(jax.tree.map(lambda x: x.sharding, tree))


@needs8
def test_inference_state_shardings_match_rule_tables():
    """On a (4, 2) mesh the InferenceState params and cache land exactly
    where the rule tables say — including BOTH branches of
    ``cache_needs_seq_shard``: olmo's divisible kv_heads shard over
    "model" (cache_seq replicated), while a ffn-mode variant flips the
    cache's sequence axis onto "model" instead."""
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    for arch, ffn_mode in (("olmo-1b", False), ("olmo-1b", True)):
        cfg = smoke_variant(get_config(arch))
        if ffn_mode:
            cfg = cfg.replace(tp_mode="ffn")
        assert cache_needs_seq_shard(cfg, mesh) == ffn_mode
        eng = InferenceEngine(cfg, mesh=mesh, slots=4, max_len=16,
                              dtype=jnp.float32)
        state = eng.init_state(T.init(cfg, jax.random.key(0)))
        rules = make_rules(cfg, mesh=mesh)
        assert rules["cache_seq"] == ("model" if ffn_mode else None)
        want = tree_shardings(T.param_specs(cfg), state.params, mesh, rules)
        assert _leaf_shardings(state.params) == jax.tree.leaves(
            want, is_leaf=lambda x: hasattr(x, "spec"))
        # the KV ring of the scanned blocks: slots axis over "data", and the
        # model axis on kv_heads (heads mode) vs cache_seq (ffn mode)
        kv = state.cache["blocks"][str(cfg.layer_pattern.index("global"))] \
            if "blocks" in state.cache else state.cache["prefix"][0]
        spec = kv.k.sharding.spec
        assert spec[1] == "data", spec
        if ffn_mode:
            assert spec[2] == "model", spec
        else:
            assert spec[3] == "model", spec
        assert state.positions.sharding.spec[0] == "data"


@needs8
@pytest.mark.slow  # ~19s on 8 host devices; CI still runs it
def test_mesh_serving_matches_single_device_tokens():
    """Greedy streams served off the (4, 2)-sharded state bit-match the
    default 1x1-mesh engine."""
    cfg = smoke_variant(get_config("qwen2-1.5b"))
    lens = [8, 6, 7, 8]
    ref, _ = _serve(cfg, _requests(cfg, lens), slots=4)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    got, _ = _serve(cfg, _requests(cfg, lens), slots=4, mesh=mesh)
    assert got == ref


@needs8
@pytest.mark.slow  # ~30s/arch on 8 host devices; CI still runs it
@pytest.mark.parametrize("arch", ["qwen2-1.5b", "gemma2-2b",
                                  "recurrentgemma-2b"])
def test_paged_vs_contiguous_parity_on_mesh(arch):
    """The PR's acceptance bar: on an 8-device (4, 2) mesh, the paged
    engine with chunked prefill produces greedy tokens identical to the
    contiguous slot-major baseline, across attention-only, local/global
    and recurrent-hybrid architectures."""
    cfg = smoke_variant(get_config(arch))
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    lens = [8, 5, 7, 6]
    ref, _ = _serve(cfg, _requests(cfg, lens), slots=4, mesh=mesh)
    got, _ = _serve(cfg, _requests(cfg, lens), slots=4, mesh=mesh,
                    paged=True, page_size=4, prefill_chunk=3)
    assert got == ref, arch


@needs8
def test_paged_pool_shardings_match_rule_tables():
    """The page pool lands where the rule tables say on a (4, 2) mesh:
    pages over "data" and — per cache_needs_seq_shard — the model axis on
    kv_heads (heads mode) vs the within-page offset axis (ffn mode).  The
    page table rides the slot axis like the position counters."""
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    for ffn_mode in (False, True):
        cfg = smoke_variant(get_config("olmo-1b"))
        if ffn_mode:
            cfg = cfg.replace(tp_mode="ffn")
        assert cache_needs_seq_shard(cfg, mesh) == ffn_mode
        eng = InferenceEngine(cfg, mesh=mesh, slots=4, max_len=16,
                              dtype=jnp.float32, paged=True, page_size=4,
                              num_pages=8)
        state = eng.init_state(T.init(cfg, jax.random.key(0)))
        kv = state.cache["blocks"][str(cfg.layer_pattern.index("global"))] \
            if "blocks" in state.cache else state.cache["prefix"][0]
        spec = kv.k.sharding.spec                      # (rep, P, ps, Hkv, D)
        assert spec[1] == "data", spec
        if ffn_mode:
            assert spec[2] == "model", spec
        else:
            assert spec[3] == "model", spec
        assert kv.pos.sharding.spec[1] == "data", kv.pos.sharding.spec
        assert state.page_table.sharding.spec[0] == "data"
        assert state.positions.sharding.spec[0] == "data"


# ---------------------------------------------------------------------------
# train -> ckpt -> serve hand-off
# ---------------------------------------------------------------------------
def test_from_train_state_hand_off_no_host_gather():
    """A live TrainState converts to an InferenceState in place: same
    buffers (donated, never gathered to host) and the served tokens match
    an engine built from an identical fresh init."""
    cfg = smoke_variant(get_config("olmo-1b"))
    tc = TrainConfig(learning_rate=1e-3, total_steps=4, warmup_steps=1)
    eng = Engine.for_lm(cfg, tc)
    tstate = eng.init_state(jax.random.key(0), T.init(cfg, jax.random.key(7)))
    # the hand-off contract: the train engine's param shardings ARE the
    # inference-side placement (non-fsdp), so the adopt is a no-op
    want = jax.tree.leaves(eng.param_shardings(tstate.params))
    ieng, istate = InferenceEngine.from_train_state(
        eng, tstate, slots=2, max_len=PROMPT + GEN, dtype=jnp.float32)
    assert ieng.mesh is eng.mesh
    assert _leaf_shardings(istate.params) == want
    sched = Scheduler(ieng, istate)
    got = sched.run(_requests(cfg, [PROMPT, PROMPT]))

    eng2 = InferenceEngine(cfg, slots=2, max_len=PROMPT + GEN,
                           dtype=jnp.float32)
    st2 = eng2.init_state(T.init(cfg, jax.random.key(7)))
    want = Scheduler(eng2, st2).run(_requests(cfg, [PROMPT, PROMPT]))
    assert got == want


def test_train_ckpt_serve_cli_roundtrip(tmp_path):
    """CLI regression: a TrainState checkpointed by repro.launch.train,
    restored by repro.launch.serve (params subtree only), forecasts the
    SAME per-watershed NSE that Engine.eval_step reports on the restored
    state."""
    env = dict(os.environ, PYTHONPATH=SRC)
    ck = str(tmp_path / "state.npz")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "domst",
         "--watersheds", "2", "--days", "120", "--epochs", "1",
         "--ckpt", ck],
        capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr[-800:]
    assert os.path.exists(ck)
    out2 = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "domst",
         "--ckpt", ck, "--watersheds", "2", "--days", "120"],
        capture_output=True, text=True, env=env, timeout=300)
    assert out2.returncode == 0, out2.stderr[-800:]
    rec = json.loads([l for l in out2.stdout.splitlines()
                      if l.startswith("{")][0])
    assert rec["restored"] and rec["watersheds"] == 2

    # reference: restore the full TrainState and eval through the engine
    cfg = get_config("domst")
    tc = TrainConfig(learning_rate=1e-3, total_steps=10, warmup_steps=1)
    eng = Engine.for_domst(cfg, tc, stacked=True)
    windows = make_domst_windows(2, 120)
    state = eng.init_state(jax.random.key(0),
                           domst.init_stacked(cfg, jax.random.key(0), 2))
    state = eng.restore(ck, state)
    ev = eng.eval_step(state, eng.place_batch(stacked_test_batch(windows)))
    np.testing.assert_allclose(np.asarray(rec["nse"]), np.asarray(ev["nse"]),
                               rtol=1e-4, atol=1e-5)
