"""Attention paths: flash (scan) / blockq (train) / local window / decode
ring-buffer — all against a naive dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import attention as A

B, S, HQ, HKV, D = 2, 37, 4, 2, 16


def naive_attention(q, k, v, causal=True, window=None, softcap=0.0):
    Bq, Sq, Hq, Dh = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    kq = jnp.repeat(k, G, axis=2)
    vq = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kq).astype(jnp.float32) / np.sqrt(Dh)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qi = jnp.arange(Sq)[:, None]
    ki = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= (qi - ki < window)
        if not causal:
            mask &= (ki - qi < window)
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vq)


@pytest.fixture(scope="module")
def qkv(rng):
    q = jnp.asarray(rng.normal(0, 1, (B, S, HQ, D)).astype("float32"))
    k = jnp.asarray(rng.normal(0, 1, (B, S, HKV, D)).astype("float32"))
    v = jnp.asarray(rng.normal(0, 1, (B, S, HKV, D)).astype("float32"))
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_naive(qkv, causal):
    q, k, v = qkv
    got = A.flash_attention(q, k, v, causal=causal, block_k=8, block_q=16)
    want = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, atol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("softcap", [0.0, 5.0])
def test_blockq_matches_naive(qkv, causal, softcap):
    q, k, v = qkv
    got = A.blockq_attention(q, k, v, causal=causal, softcap_val=softcap,
                             block_q=8)
    want = naive_attention(q, k, v, causal=causal, softcap=softcap)
    np.testing.assert_allclose(got, want, atol=2e-5)


@pytest.mark.parametrize("window", [4, 16, 64])
def test_local_matches_naive(qkv, window):
    q, k, v = qkv
    got = A.local_attention(q, k, v, window=window, causal=True, block_q=8)
    want = naive_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_blockq_grad_finite(qkv):
    q, k, v = qkv
    g = jax.grad(lambda q_: jnp.sum(A.blockq_attention(q_, k, v) ** 2))(q)
    assert bool(jnp.all(jnp.isfinite(g)))


def test_decode_ring_buffer_matches_full(rng, key):
    """Stream tokens through decode_attention with a ring cache of size W
    and compare against windowed attention over the full sequence."""
    cfg = smoke_variant(get_config("gemma2-2b")).replace(
        window=8, rope=True, attn_softcap=0.0, qk_norm=False)
    from repro.models.attention import attn_params
    from repro.distributed.sharding import ParamFactory
    params = attn_params(ParamFactory(key), cfg)
    T = 20
    x = jnp.asarray(rng.normal(0, 1, (B, T, cfg.d_model)).astype("float32"))

    # reference: full-sequence local attention block
    ref = A.attention_block(params, cfg, x, kind="local")

    cache = A.init_kv_cache(B, cfg.window, cfg.num_kv_heads,
                            cfg.resolved_head_dim(), dtype=jnp.float32)
    outs = []
    for t in range(T):
        o, cache = A.decode_attention(params, cfg, x[:, t:t + 1], cache,
                                      jnp.asarray(t, jnp.int32),
                                      window=cfg.window)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    # atol sized for XLA reassociation noise across device-count configs
    # (CI forces 8 host devices); values are O(40), so this is ~5e-5 rel.
    np.testing.assert_allclose(got, ref, atol=2e-3)
