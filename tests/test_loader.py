"""Async sharded data loading (repro/data/loader.py, paper Fig. 2a "I.P."):
prefetch-vs-sync parity, deterministic epoch shuffles, resume-cursor
round-trips (Dom-ST and LM identically), engine eval_step, and sharding of
loader outputs on a forced multi-device mesh."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import TrainConfig, get_config, smoke_variant
from repro.core import domst
from repro.data import generate_all_watersheds, make_training_windows
from repro.data.loader import ShardedLoader
from repro.data.pipeline import (
    InputPipeline, StackedSource, WatershedSource, stacked_test_batch,
    train_test_split,
)
from repro.data.tokens import TokenSource, synthetic_token_batch
from repro.train import Engine

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def hydro():
    data = generate_all_watersheds(3, num_days=120)
    windows = [make_training_windows(w) for w in data.values()]
    return windows, InputPipeline(windows, batch_size=8, seed=0)


def _tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class HostOnly:
    """Engine stand-in whose placement is the identity, for loader tests
    that compare host batches without touching devices."""

    @staticmethod
    def place_batch(b):
        return b


# ---------------------------------------------------------------------------
# DataSources: step-indexed access matches the legacy epoch generators
# ---------------------------------------------------------------------------
def test_stacked_source_matches_legacy_generator(hydro):
    windows, ip = hydro
    src = StackedSource(ip)
    step = 0
    for epoch in range(2):
        for ref in ip.stacked_batches(epoch):
            got = src.host_batch(step)
            assert set(got) == set(ref)
            for k in ref:
                np.testing.assert_array_equal(got[k], ref[k])
            step += 1
    assert step == 2 * src.steps_per_epoch


def test_watershed_source_matches_legacy_generator(hydro):
    windows, ip = hydro
    w = windows[1]
    src = WatershedSource(ip, w)
    step = 0
    for epoch in range(2):
        for ref in ip.batches(w, epoch):
            got = src.host_batch(step)
            for k in ref:
                np.testing.assert_array_equal(got[k], ref[k])
            step += 1


def test_epoch_shuffles_deterministic_and_distinct(hydro):
    windows, ip = hydro
    w = windows[0]
    # same (seed, watershed, epoch) -> same order; fresh source instance too
    a = WatershedSource(ip, w)
    b = WatershedSource(ip, w)
    np.testing.assert_array_equal(a.host_batch(3)["discharge"],
                                  b.host_batch(3)["discharge"])
    # different epochs and different pipeline seeds reshuffle
    assert not np.array_equal(ip.epoch_order(w, 0), ip.epoch_order(w, 1))
    ip2 = InputPipeline(windows, batch_size=8, seed=7)
    assert not np.array_equal(ip.epoch_order(w, 0), ip2.epoch_order(w, 0))


# ---------------------------------------------------------------------------
# ShardedLoader: prefetch parity, cursor resume
# ---------------------------------------------------------------------------
def test_prefetch_matches_sync_bit_for_bit(hydro):
    """The acceptance bar: loss curve and final params through the
    prefetching loader are IDENTICAL to the synchronous path."""
    windows, ip = hydro
    cfg = get_config("domst")
    tc = TrainConfig(learning_rate=1e-3, total_steps=50, warmup_steps=2)
    src = StackedSource(ip)

    def run(prefetch):
        eng = Engine.for_domst(cfg, tc, stacked=True)
        state = eng.init_state(
            jax.random.key(0), domst.init_stacked(cfg, jax.random.key(0), 3))
        losses = []
        loader = ShardedLoader(src, eng, prefetch=prefetch,
                               num_steps=2 * src.steps_per_epoch)
        for b in loader:
            state, m = eng.step(state, b)
            losses.append(np.asarray(m["loss"]))
        return state, np.stack(losses), loader

    state_s, loss_s, _ = run(0)
    state_p, loss_p, loader = run(3)
    np.testing.assert_array_equal(loss_s, loss_p)
    _tree_equal(state_s.params, state_p.params)
    assert int(state_p.step) == loader.cursor == 2 * src.steps_per_epoch


def test_resume_cursor_roundtrip_domst_and_lm(hydro):
    """--resume regression: a loader restarted at cursor k yields exactly
    the continuation of the uninterrupted stream — mid-epoch included and
    identically for the Dom-ST (stacked) and LM (token) sources."""
    windows, ip = hydro
    cfg = smoke_variant(get_config("olmo-1b"))
    for src in (StackedSource(ip), TokenSource(cfg, 4, 16, seed=0)):
        full = list(ShardedLoader(src, HostOnly, prefetch=2, num_steps=9))
        k = 4  # mid-epoch for the stacked source (spe is > 4 here)
        resumed = ShardedLoader(src, HostOnly, prefetch=2, start_step=k,
                                num_steps=9 - k)
        for ref, got in zip(full[k:], resumed):
            for key in ref:
                np.testing.assert_array_equal(got[key], ref[key])
        assert resumed.cursor == 9


def test_loader_sync_mode_matches_prefetch_batches(hydro):
    windows, ip = hydro
    src = StackedSource(ip)
    a = list(ShardedLoader(src, HostOnly, prefetch=0, num_steps=5))
    b = list(ShardedLoader(src, HostOnly, prefetch=4, num_steps=5))
    for x, y in zip(a, b):
        for k in x:
            np.testing.assert_array_equal(x[k], y[k])


def test_loader_propagates_source_errors():
    class Broken:
        steps_per_epoch = None

        def host_batch(self, step):
            if step >= 2:
                raise RuntimeError("boom at step 2")
            return {"x": np.zeros(3)}

    it = iter(ShardedLoader(Broken(), HostOnly, prefetch=2, num_steps=5))
    assert next(it) is not None
    next(it)
    with pytest.raises(RuntimeError, match="boom"):
        next(it)


# ---------------------------------------------------------------------------
# Engine.eval_step: held-out metrics on the live sharded state
# ---------------------------------------------------------------------------
def test_eval_step_stacked_per_watershed_nse(hydro):
    windows, ip = hydro
    cfg = get_config("domst")
    tc = TrainConfig(learning_rate=1e-3, total_steps=30, warmup_steps=2)
    eng = Engine.for_domst(cfg, tc, stacked=True)
    state = eng.init_state(
        jax.random.key(0), domst.init_stacked(cfg, jax.random.key(0), 3))
    src = StackedSource(ip)
    for b in ShardedLoader(src, eng, num_steps=src.steps_per_epoch):
        state, _ = eng.step(state, b)
    ev = eng.eval_step(state, eng.place_batch(stacked_test_batch(windows)))
    assert ev["nse"].shape == (3,) and ev["mse"].shape == (3,)
    # matches the host-side per-watershed evaluate() on pulled params
    for i, w in enumerate(windows):
        p = jax.tree.map(lambda x: x[i], state.params)
        _, te = train_test_split(w)
        ref = domst.evaluate(p, cfg, {k: jnp.asarray(v) for k, v in te.items()})
        np.testing.assert_allclose(float(ev["nse"][i]), float(ref["nse"]),
                                   rtol=1e-5, atol=1e-5)


def test_eval_step_requires_eval_fn():
    tc = TrainConfig()
    eng = Engine(lambda p, b: (jnp.zeros(()), {}), tc)
    with pytest.raises(ValueError, match="eval_fn"):
        eng.eval_step(None, {})


# ---------------------------------------------------------------------------
# Sharded placement on a real multi-device mesh (CI forces 8 host devices)
# ---------------------------------------------------------------------------
@pytest.mark.skipif(len(jax.devices()) < 8,
                    reason="needs 8 host devices (CI sets XLA_FLAGS)")
def test_loader_outputs_sharded_on_mesh():
    """Loader batches arrive on the (4, 2) mesh with the watershed axis
    sharded over "data" — already matching the step's in_shardings — and
    train + eval run off them."""
    data = generate_all_watersheds(4, num_days=120)
    windows = [make_training_windows(w) for w in data.values()]
    ip = InputPipeline(windows, batch_size=8, seed=0)
    cfg = get_config("domst")
    tc = TrainConfig(learning_rate=1e-3, total_steps=10, warmup_steps=1)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    eng = Engine.for_domst(cfg, tc, mesh=mesh, stacked=True)
    state = eng.init_state(
        jax.random.key(0), domst.init_stacked(cfg, jax.random.key(0), 4))
    src = StackedSource(ip)
    loader = ShardedLoader(src, eng, prefetch=2, num_steps=3)
    for b in loader:
        spec = b["precip"].sharding.spec
        assert spec and spec[0] == "data", spec
        state, m = eng.step(state, b)
    assert np.isfinite(float(np.mean(np.asarray(m["loss"]))))
    ev = eng.eval_step(state, eng.place_batch(stacked_test_batch(windows)))
    assert ev["nse"].shape == (4,)


# ---------------------------------------------------------------------------
# CLI regression: checkpoint -> resume continues the stream through the
# loader cursor on the stacked path
# ---------------------------------------------------------------------------
def test_train_cli_resume_roundtrip(tmp_path):
    env = dict(os.environ, PYTHONPATH=SRC)
    ck = str(tmp_path / "state.npz")
    common = [sys.executable, "-m", "repro.launch.train", "--arch", "domst",
              "--watersheds", "2", "--days", "120", "--epochs", "1"]
    out = subprocess.run(common + ["--ckpt", ck, "--eval-interval", "2"],
                         capture_output=True, text=True, env=env, timeout=300)
    assert out.returncode == 0, out.stderr[-800:]
    assert "eval mean NSE" in out.stdout       # the periodic eval hook ran
    assert "epoch 0 mean loss" in out.stdout
    assert os.path.exists(ck)
    out2 = subprocess.run(common + ["--resume", ck], capture_output=True,
                          text=True, env=env, timeout=300)
    assert out2.returncode == 0, out2.stderr[-800:]
    assert "mean_nse" in out2.stdout
    # the loader cursor continued past the first run instead of replaying:
    # the resumed epoch of steps logs as epoch 1, not epoch 0
    assert "epoch 1 mean loss" in out2.stdout
    assert "epoch 0 mean loss" not in out2.stdout
