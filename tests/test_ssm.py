"""Mamba-2 SSD: chunked dual form vs naive recurrence; decode chain."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import ssm as M
from repro.models.ssm import ssd_chunked


def naive_ssd(xh, dt, A, Bm, Cm, D):
    """Sequential recurrence h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t."""
    Bsz, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = np.repeat(np.asarray(Bm), rep, axis=2)
    Ch = np.repeat(np.asarray(Cm), rep, axis=2)
    x = np.asarray(xh, np.float64)
    dtn = np.asarray(dt, np.float64)
    An = np.asarray(A, np.float64)
    h = np.zeros((Bsz, H, P, N))
    ys = np.zeros_like(x)
    for t in range(S):
        decay = np.exp(dtn[:, t] * An[None, :])                 # (B,H)
        h = h * decay[..., None, None]
        h = h + np.einsum("bhp,bhn->bhpn", x[:, t] * dtn[:, t][..., None],
                          Bh[:, t])
        ys[:, t] = np.einsum("bhpn,bhn->bhp", h, Ch[:, t])
    ys = ys + x * np.asarray(D)[None, None, :, None]
    return ys, h


@pytest.mark.parametrize("S,chunk", [(16, 4), (15, 4), (32, 8), (7, 16)])
def test_ssd_chunked_matches_naive(rng, S, chunk):
    Bsz, H, P, G, N = 2, 4, 8, 1, 16
    xh = jnp.asarray(rng.normal(0, 1, (Bsz, S, H, P)).astype("float32"))
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (Bsz, S, H)).astype("float32"))
    A = jnp.asarray(-rng.uniform(0.1, 1.0, H).astype("float32"))
    Bm = jnp.asarray(rng.normal(0, 1, (Bsz, S, G, N)).astype("float32"))
    Cm = jnp.asarray(rng.normal(0, 1, (Bsz, S, G, N)).astype("float32"))
    D = jnp.asarray(rng.normal(0, 1, H).astype("float32"))
    y, hT = ssd_chunked(xh, dt, A, Bm, Cm, D, chunk)
    y_ref, h_ref = naive_ssd(xh, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hT), h_ref, atol=2e-4)


def test_decode_chain_matches_block(rng, key):
    cfg = smoke_variant(get_config("mamba2-130m"))
    from repro.distributed.sharding import ParamFactory
    params = M.ssm_params(ParamFactory(key), cfg)
    T = 12
    x = jnp.asarray(rng.normal(0, 1, (2, T, cfg.d_model)).astype("float32"))
    full, state_T = M.ssm_block(params, cfg, x, return_state=True)
    state = M.init_ssm_state(cfg, 2)
    outs = []
    for t in range(T):
        o, state = M.ssm_decode_step(params, cfg, x[:, t:t + 1], state)
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full), atol=3e-4)
    np.testing.assert_allclose(np.asarray(state.h), np.asarray(state_T.h),
                               atol=3e-4)


def test_ssd_grad_finite(rng, key):
    cfg = smoke_variant(get_config("mamba2-130m"))
    from repro.distributed.sharding import ParamFactory
    params = M.ssm_params(ParamFactory(key), cfg)
    x = jnp.asarray(rng.normal(0, 1, (2, 16, cfg.d_model)).astype("float32"))
    g = jax.grad(lambda p: jnp.sum(M.ssm_block(p, cfg, x) ** 2))(params)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))
