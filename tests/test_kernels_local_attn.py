"""Sliding-window flash attention kernel: sweep vs oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.local_attn.ops import local_attention_fused
from repro.kernels.local_attn.ref import local_attention_ref


def _mk(rng, B, S, Hq, Hkv, D, dtype):
    q = jnp.asarray(rng.normal(0, 1, (B, S, Hq, D)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), dtype)
    return q, k, v


@pytest.mark.parametrize("S,window,bq", [
    (32, 8, 8), (64, 16, 16), (48, 16, 8), (40, 64, 8), (128, 32, 16),
])
@pytest.mark.parametrize("Hq,Hkv", [(4, 2), (2, 2), (4, 1)])
def test_sweep_matches_ref(rng, S, window, bq, Hq, Hkv):
    q, k, v = _mk(rng, 2, S, Hq, Hkv, 16, jnp.float32)
    got = local_attention_fused(q, k, v, window=window, block_q=bq)
    want = local_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(rng, dtype):
    q, k, v = _mk(rng, 1, 32, 2, 1, 32, dtype)
    got = local_attention_fused(q, k, v, window=16, block_q=8)
    want = local_attention_ref(q, k, v, window=16)
    tol = 2e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_matches_model_local_attention(rng):
    """Kernel == models.attention.local_attention (the pure-JAX path)."""
    from repro.models.attention import local_attention
    q, k, v = _mk(rng, 2, 64, 4, 2, 16, jnp.float32)
    got = local_attention_fused(q, k, v, window=16, block_q=16)
    want = local_attention(q, k, v, window=16, causal=True, block_q=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5)


def test_unaligned_seq_padding(rng):
    q, k, v = _mk(rng, 1, 37, 2, 2, 16, jnp.float32)
    got = local_attention_fused(q, k, v, window=8, block_q=16)
    want = local_attention_ref(q, k, v, window=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("S,bq", [(37, 16), (45, 8), (100, 64)])
def test_unaligned_noncausal_padding(rng, S, bq):
    """Non-causal + S % block_q != 0: padded keys sit AHEAD of the tail
    queries, inside their window — they must be masked (regression)."""
    q, k, v = _mk(rng, 2, S, 4, 2, 16, jnp.float32)
    got = local_attention_fused(q, k, v, window=8, causal=False, block_q=bq)
    want = local_attention_ref(q, k, v, window=8, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
