"""launch/steps input specs + mesh constructor (pure shape logic, 1 device)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES, get_config
from repro.launch.steps import batch_struct, input_specs
from repro.launch.dryrun import matrix, parse_collectives


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_specs_cover_targets(arch):
    cfg = get_config(arch)
    shape = INPUT_SHAPES["train_4k"]
    b = batch_struct(cfg, shape)
    assert "targets" in b
    if cfg.family == "audio":
        assert b["frames"].shape == (256, 4096, cfg.frontend_dim)
    elif cfg.family == "vlm":
        assert b["patches"].shape[1] == cfg.num_patches
        # patch prefix + text == seq_len
        assert b["tokens"].shape[1] + cfg.num_patches == 4096
    else:
        assert b["tokens"].shape == (256, 4096)


@pytest.mark.parametrize("arch", [a for a in ASSIGNED_ARCHS
                                  if get_config(a).supports_decode()])
def test_decode_specs_have_cache(arch):
    cfg = get_config(arch)
    specs = input_specs(cfg, "decode_32k")
    assert specs["batch"]["tokens"].shape == (128, 1)
    assert specs["position"].shape == ()
    leaves = jax.tree.leaves(specs["cache"])
    assert leaves, "cache must be non-empty"
    # KV caches sized by seq_len (or window for local layers)
    total = sum(l.size * l.dtype.itemsize for l in leaves)
    assert total > 0


def test_matrix_has_documented_skips():
    combos = matrix()
    assert len(combos) == 32
    archs = {a for a, _ in combos}
    assert "gemma2-2b-localonly" in archs          # long-context variant
    assert ("hubert-xlarge", "decode_32k") not in combos
    assert ("olmo-1b", "long_500k") not in combos
    assert ("mamba2-130m", "long_500k") in combos
    assert ("recurrentgemma-2b", "long_500k") in combos


def test_parse_collectives():
    hlo = """
  %ar = f32[2,4] all-reduce(%x), replica_groups={}
  %ag.1 = bf16[8,16] all-gather(%y), dims={0}
  %a2a = (f32[4], f32[4]) all-to-all(%p, %q)
  %cp-start = f32[2] collective-permute-start(%z)
"""
    out = parse_collectives(hlo)
    assert out["bytes"]["all-reduce"] == 32
    assert out["bytes"]["all-gather"] == 256
    assert out["bytes"]["all-to-all"] == 32
    assert out["counts"]["collective-permute"] == 1


def test_mesh_constants():
    from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
    assert PEAK_FLOPS_BF16 == 197e12 and HBM_BW == 819e9 and ICI_BW == 50e9
