"""Stack-level invariants: decode == full forward, causality, vlm prefix."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, smoke_variant
from repro.data.tokens import synthetic_token_batch
from repro.models import transformer as T
from repro.models.layers import unembed

S = 16


def _ample_moe(cfg):
    if cfg.moe is not None:
        return cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                   capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("arch", [a for a in ASSIGNED_ARCHS
                                  if get_config(a).supports_decode()])
def test_decode_matches_full_forward(arch, key):
    cfg = _ample_moe(smoke_variant(get_config(arch)))
    params = T.init(cfg, key)
    batch = {k: jnp.asarray(v)
             for k, v in synthetic_token_batch(cfg, 2, S).items()}
    x, _ = T.forward(params, cfg, batch, dtype=jnp.float32)
    want = unembed(params["embed"], x[:, -1:], tie=cfg.tie_embeddings,
                   cap=cfg.logit_softcap)[:, 0]
    if cfg.family == "vlm":
        pre = {"patches": batch["patches"], "tokens": batch["tokens"][:, :-1]}
        pos = cfg.num_patches + batch["tokens"].shape[1] - 1
    else:
        pre = {"tokens": batch["tokens"][:, :-1]}
        pos = S - 1
    _, cache = T.prefill(params, cfg, pre, max_len=S + cfg.num_patches + 4,
                         dtype=jnp.float32)
    got, _ = T.decode_step(params, cfg, {"tokens": batch["tokens"][:, -1:]},
                           cache, jnp.asarray(pos, jnp.int32),
                           dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_causality(key):
    """Future tokens must not affect past logits (causal archs)."""
    cfg = smoke_variant(get_config("olmo-1b"))
    params = T.init(cfg, key)
    t1 = jnp.ones((1, S), jnp.int32) * 3
    t2 = t1.at[:, -1].set(7)                                    # change last token
    x1, _ = T.forward(params, cfg, {"tokens": t1}, dtype=jnp.float32)
    x2, _ = T.forward(params, cfg, {"tokens": t2}, dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(x1[:, :-1]), np.asarray(x2[:, :-1]),
                               atol=1e-6)
    assert float(jnp.max(jnp.abs(x1[:, -1] - x2[:, -1]))) > 1e-4


def test_encoder_is_bidirectional(key):
    cfg = smoke_variant(get_config("hubert-xlarge"))
    params = T.init(cfg, key)
    f = jnp.asarray(np.random.default_rng(0).normal(
        0, 1, (1, S, cfg.frontend_dim)).astype("float32"))
    f2 = f.at[:, -1].add(1.0)
    x1, _ = T.forward(params, cfg, {"frames": f}, dtype=jnp.float32)
    x2, _ = T.forward(params, cfg, {"frames": f2}, dtype=jnp.float32)
    # encoder: a change in the LAST frame must affect EARLIER positions
    assert float(jnp.max(jnp.abs(x1[:, 0] - x2[:, 0]))) > 1e-6


def test_vlm_patch_prefix_changes_text_logits(key):
    cfg = smoke_variant(get_config("internvl2-2b"))
    params = T.init(cfg, key)
    batch = {k: jnp.asarray(v)
             for k, v in synthetic_token_batch(cfg, 1, S).items()}
    x1, _ = T.forward(params, cfg, batch, dtype=jnp.float32)
    batch2 = dict(batch)
    batch2["patches"] = batch["patches"] + 1.0
    x2, _ = T.forward(params, cfg, batch2, dtype=jnp.float32)
    assert float(jnp.max(jnp.abs(x1 - x2))) > 1e-4


def test_loss_mask_respected(key):
    cfg = smoke_variant(get_config("hubert-xlarge"))
    params = T.init(cfg, key)
    b = {k: jnp.asarray(v) for k, v in synthetic_token_batch(cfg, 2, S).items()}
    l1, _ = T.lm_loss(params, cfg, b, dtype=jnp.float32)
    # flipping targets at UNmasked positions must not change the loss
    tweaked = dict(b)
    flip = (1 - b["loss_mask"]).astype(bool)
    tweaked["targets"] = jnp.where(flip, (b["targets"] + 1) % cfg.vocab_size,
                                   b["targets"])
    l2, _ = T.lm_loss(params, cfg, tweaked, dtype=jnp.float32)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_pattern_stack_plan():
    cfg = get_config("recurrentgemma-2b")
    prefix, pat, n_rep, suffix = T.stack_plan(cfg)
    assert len(prefix) == 0 and pat == ("recurrent", "recurrent", "local")
    assert n_rep == 8 and suffix == ("recurrent", "recurrent")
    assert len(prefix) + n_rep * len(pat) + len(suffix) == cfg.num_layers
    cfg2 = get_config("deepseek-moe-16b")
    prefix, pat, n_rep, suffix = T.stack_plan(cfg2)
    assert len(prefix) == 1 and n_rep == 27 and not suffix
