"""Checkpoint save/restore round-trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs import get_config, smoke_variant
from repro.models import transformer as tfm


def test_roundtrip(tmp_path, key):
    cfg = smoke_variant(get_config("qwen2-1.5b"))
    params = tfm.init(cfg, key)
    path = str(tmp_path / "ck.npz")
    ckpt.save(path, params)
    example = tfm.init(cfg, jax.random.key(99))      # different values
    restored = ckpt.restore(path, example)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_shape_mismatch_raises(tmp_path, key):
    cfg = smoke_variant(get_config("qwen2-1.5b"))
    params = tfm.init(cfg, key)
    path = str(tmp_path / "ck.npz")
    ckpt.save(path, params)
    cfg2 = smoke_variant(get_config("olmo-1b"))
    with pytest.raises((ValueError, KeyError)):
        ckpt.restore(path, tfm.init(cfg2, key))


def test_opt_state_roundtrip(tmp_path, key):
    from repro.configs import TrainConfig
    from repro.optim import make_optimizer
    cfg = smoke_variant(get_config("olmo-1b"))
    params = tfm.init(cfg, key)
    opt = make_optimizer(TrainConfig())[0](params)
    path = str(tmp_path / "opt.npz")
    ckpt.save(path, opt)
    restored = ckpt.restore(path, opt)
    assert int(restored.step) == int(opt.step)
