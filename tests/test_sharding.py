"""Logical-axis sharding rule engine + 1-device end-to-end pjit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config, smoke_variant
from repro.distributed.sharding import (
    ParamFactory, make_rules, resolve_pspec, tree_pspecs,
)
from repro.models import transformer as tfm


@pytest.fixture(scope="module")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_rules_head_vs_ffn_mode(mesh11):
    r_h = make_rules(get_config("olmo-1b"), mesh=mesh11)
    r_f = make_rules(get_config("qwen2-1.5b"), mesh=mesh11)
    assert r_h["heads"] == "model" and r_f["heads"] is None
    assert r_f["ffn"] == "model"
    assert r_f["cache_seq"] == "model" and r_h["cache_seq"] is None
    assert r_h["batch"] == "data"


def test_resolve_pspec_divisibility():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = {"heads": "model", "embed": None, "batch": "data"}
    # 1-way axes always divide
    assert resolve_pspec(("batch", None, "heads"), (4, 7, 16), mesh, rules) \
        == P("data", None, "model")


def test_resolve_pspec_indivisible_replicates(monkeypatch):
    """pjit argument shardings require exact divisibility, so any
    indivisible dim replicates (24 or 8 heads on a 16-way axis)."""
    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)
    rules = {"heads": "model", "batch": "data"}
    assert resolve_pspec(("heads",), (24,), FakeMesh, rules) == P(None)
    assert resolve_pspec(("heads",), (8,), FakeMesh, rules) == P(None)
    assert resolve_pspec(("heads",), (32,), FakeMesh, rules) == P("model")
    # no duplicate mesh axes across dims
    spec = resolve_pspec(("batch", "batch"), (32, 32), FakeMesh, rules)
    assert spec == P("data", None)


def test_param_specs_align_with_params(key):
    """spec tree and param tree must be structurally identical."""
    for arch in ("olmo-1b", "deepseek-moe-16b", "mamba2-130m",
                 "recurrentgemma-2b", "gemma2-2b", "internvl2-2b"):
        cfg = smoke_variant(get_config(arch))
        params = tfm.init(cfg, key)
        specs = tfm.param_specs(cfg)
        is_axes = lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
        s_paths = [p for p, _ in
                   jax.tree_util.tree_flatten_with_path(
                       specs, is_leaf=is_axes)[0]]
        p_paths = [p for p, _ in
                   jax.tree_util.tree_flatten_with_path(params)[0]]
        assert s_paths == p_paths, arch
        # ndim of every axes tuple matches the param
        flat_s = jax.tree.leaves(specs, is_leaf=is_axes)
        flat_p = jax.tree.leaves(params)
        for ax, arr in zip(flat_s, flat_p):
            assert len(ax) == arr.ndim


def test_tree_pspecs_resolution(mesh11, key):
    cfg = smoke_variant(get_config("olmo-1b"))
    rules = make_rules(cfg, mesh=mesh11)
    params = tfm.init(cfg, key)
    specs = tfm.param_specs(cfg)
    pspecs = tree_pspecs(specs, params, mesh11, rules)
    flat = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(p, P) for p in flat)
    assert len(flat) == len(jax.tree.leaves(params))


def test_jit_train_step_on_1x1_mesh(key):
    """End-to-end pjit with shardings on the single-device mesh."""
    from repro.configs import INPUT_SHAPES, TrainConfig
    from repro.launch.steps import (
        batch_pspecs, batch_struct, make_train_step_fn, opt_pspecs,
        param_pspecs,
    )
    from repro.optim import make_optimizer
    from repro.data.tokens import synthetic_token_batch
    import dataclasses
    cfg = smoke_variant(get_config("olmo-1b"))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = make_rules(cfg, mesh=mesh)
    tc = TrainConfig(total_steps=10, warmup_steps=1)
    pspecs, _ = param_pspecs(cfg, mesh, rules)
    from jax.sharding import NamedSharding
    ns = lambda t: jax.tree.map(lambda p: NamedSharding(mesh, p), t,
                                is_leaf=lambda x: isinstance(x, P))
    params = tfm.init(cfg, key)
    opt = make_optimizer(tc)[0](params)
    fn = jax.jit(make_train_step_fn(cfg, tc),
                 in_shardings=(ns(pspecs), ns(opt_pspecs(pspecs, tc)), None),
                 out_shardings=(ns(pspecs), ns(opt_pspecs(pspecs, tc)), None))
    b = {k: jnp.asarray(v)
         for k, v in synthetic_token_batch(cfg, 2, 16).items()}
    p2, o2, m = fn(params, opt, b)
    assert np.isfinite(float(m["loss"]))
