"""Logical-axis sharding rule engine + 1-device end-to-end pjit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config, smoke_variant
from repro.distributed.sharding import (
    ParamFactory, _cache_needs_seq_shard, make_rules, resolve_pspec,
    tree_pspecs,
)
from repro.models import transformer as tfm


class _FakeMesh:
    """Shape-only mesh stand-in (rule resolution needs names + sizes)."""
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape, dtype=object)


@pytest.fixture(scope="module")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_rules_head_vs_ffn_mode(mesh11):
    r_h = make_rules(get_config("olmo-1b"), mesh=mesh11)
    r_f = make_rules(get_config("qwen2-1.5b"), mesh=mesh11)
    assert r_h["heads"] == "model" and r_f["heads"] is None
    assert r_f["ffn"] == "model"
    assert r_f["cache_seq"] == "model" and r_h["cache_seq"] is None
    assert r_h["batch"] == "data"


def test_resolve_pspec_divisibility():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = {"heads": "model", "embed": None, "batch": "data"}
    # 1-way axes always divide
    assert resolve_pspec(("batch", None, "heads"), (4, 7, 16), mesh, rules) \
        == P("data", None, "model")


def test_resolve_pspec_indivisible_replicates(monkeypatch):
    """pjit argument shardings require exact divisibility, so any
    indivisible dim replicates (24 or 8 heads on a 16-way axis)."""
    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)
    rules = {"heads": "model", "batch": "data"}
    assert resolve_pspec(("heads",), (24,), FakeMesh, rules) == P(None)
    assert resolve_pspec(("heads",), (8,), FakeMesh, rules) == P(None)
    assert resolve_pspec(("heads",), (32,), FakeMesh, rules) == P("model")
    # no duplicate mesh axes across dims
    spec = resolve_pspec(("batch", "batch"), (32, 32), FakeMesh, rules)
    assert spec == P("data", None)


def test_resolve_pspec_tuple_assignment_divisibility():
    """A ("pod","data") multi-axis assignment needs divisibility by the
    PRODUCT of the axis sizes; otherwise the dim replicates."""
    mesh = _FakeMesh((2, 8, 4), ("pod", "data", "model"))
    rules = {"batch": ("pod", "data")}
    assert resolve_pspec(("batch",), (32,), mesh, rules) == P(("pod", "data"))
    assert resolve_pspec(("batch",), (24,), mesh, rules) == P(None)   # 24 % 16
    # unknown logical axes and None entries replicate
    assert resolve_pspec((None, "nosuch"), (8, 8), mesh, rules) == P(None, None)


def test_fsdp_embed_rule_resolution():
    """fsdp=True is the parameter-rule variant: the embed dim shards over
    the data axes (both of them on a pod mesh), and indivisible embed dims
    still fall back to replication."""
    mesh2 = _FakeMesh((4, 2), ("data", "model"))
    r = make_rules(get_config("olmo-1b"), mesh=mesh2, fsdp=True)
    assert r["embed"] == "data"
    assert resolve_pspec(("embed", "ffn"), (64, 8), mesh2, r) \
        == P("data", "model")
    assert resolve_pspec(("embed",), (6,), mesh2, r) == P(None)       # 6 % 4
    # pod mesh: embed shards over the combined ("pod", "data") axes
    mesh3 = _FakeMesh((2, 8, 4), ("pod", "data", "model"))
    r3 = make_rules(get_config("olmo-1b"), mesh=mesh3, fsdp=True)
    assert r3["embed"] == ("pod", "data")
    # activation rules are untouched by the variant
    assert make_rules(get_config("olmo-1b"), mesh=mesh3)["embed"] is None


def test_cache_needs_seq_shard():
    """KV-cache seq axis shards over "model" exactly when the KV heads
    can't: ffn-mode archs always, heads-mode only on indivisibility."""
    mesh = _FakeMesh((1, 16), ("data", "model"))
    qwen = get_config("qwen2-1.5b")                 # tp_mode == "ffn"
    olmo = get_config("olmo-1b")                    # tp_mode == "heads"
    assert _cache_needs_seq_shard(qwen, mesh, "ffn") is True
    assert _cache_needs_seq_shard(None, None, "heads") is False
    # olmo-1b: kv_heads=16 divides a 16-way model axis -> no seq shard
    assert _cache_needs_seq_shard(olmo, mesh, "heads") is False
    # 12-way model axis: 16 % 12 != 0 -> the cache must shard on seq
    mesh12 = _FakeMesh((1, 12), ("data", "model"))
    assert _cache_needs_seq_shard(olmo, mesh12, "heads") is True
    # and make_rules threads the result into the rule table
    assert make_rules(olmo, mesh=mesh12)["cache_seq"] == "model"
    assert make_rules(olmo, mesh=mesh)["cache_seq"] is None


def test_param_specs_align_with_params(key):
    """spec tree and param tree must be structurally identical."""
    for arch in ("olmo-1b", "deepseek-moe-16b", "mamba2-130m",
                 "recurrentgemma-2b", "gemma2-2b", "internvl2-2b"):
        cfg = smoke_variant(get_config(arch))
        params = tfm.init(cfg, key)
        specs = tfm.param_specs(cfg)
        is_axes = lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
        s_paths = [p for p, _ in
                   jax.tree_util.tree_flatten_with_path(
                       specs, is_leaf=is_axes)[0]]
        p_paths = [p for p, _ in
                   jax.tree_util.tree_flatten_with_path(params)[0]]
        assert s_paths == p_paths, arch
        # ndim of every axes tuple matches the param
        flat_s = jax.tree.leaves(specs, is_leaf=is_axes)
        flat_p = jax.tree.leaves(params)
        for ax, arr in zip(flat_s, flat_p):
            assert len(ax) == arr.ndim


def test_tree_pspecs_resolution(mesh11, key):
    cfg = smoke_variant(get_config("olmo-1b"))
    rules = make_rules(cfg, mesh=mesh11)
    params = tfm.init(cfg, key)
    specs = tfm.param_specs(cfg)
    pspecs = tree_pspecs(specs, params, mesh11, rules)
    flat = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(p, P) for p in flat)
    assert len(flat) == len(jax.tree.leaves(params))


def test_jit_train_step_on_1x1_mesh(key):
    """End-to-end pjit with shardings on the single-device mesh."""
    from repro.configs import INPUT_SHAPES, TrainConfig
    from repro.launch.steps import (
        batch_pspecs, batch_struct, make_train_step_fn, opt_pspecs,
        param_pspecs,
    )
    from repro.optim import make_optimizer
    from repro.data.tokens import synthetic_token_batch
    import dataclasses
    cfg = smoke_variant(get_config("olmo-1b"))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = make_rules(cfg, mesh=mesh)
    tc = TrainConfig(total_steps=10, warmup_steps=1)
    pspecs, _ = param_pspecs(cfg, mesh, rules)
    from jax.sharding import NamedSharding
    ns = lambda t: jax.tree.map(lambda p: NamedSharding(mesh, p), t,
                                is_leaf=lambda x: isinstance(x, P))
    params = tfm.init(cfg, key)
    opt = make_optimizer(tc)[0](params)
    fn = jax.jit(make_train_step_fn(cfg, tc),
                 in_shardings=(ns(pspecs), ns(opt_pspecs(pspecs, tc)), None),
                 out_shardings=(ns(pspecs), ns(opt_pspecs(pspecs, tc)), None))
    b = {k: jnp.asarray(v)
         for k, v in synthetic_token_batch(cfg, 2, 16).items()}
    p2, o2, m = fn(params, opt, b)
    assert np.isfinite(float(m["loss"]))
