"""Train-step features: gradient-accumulation equivalence, contribution
gate, FSDP rule variant."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_config, smoke_variant
from repro.data.tokens import synthetic_token_batch
from repro.launch.steps import make_train_step_fn
from repro.models import transformer as tfm
from repro.optim import make_optimizer


def test_grad_accum_equivalent_to_full_batch(key):
    """grad_accum=4 must produce the same update as one full batch
    (same tokens, loss is a mean -> averaging microbatch grads matches)."""
    cfg = smoke_variant(get_config("olmo-1b"))
    batch = {k: jnp.asarray(v)
             for k, v in synthetic_token_batch(cfg, 8, 16).items()}
    params = tfm.init(cfg, key)
    outs = {}
    # SGD: the update is proportional to the grad, so bf16 reassociation
    # noise stays small (Adam would sign-normalize near-zero grads and
    # amplify it).
    for A in (1, 4):
        tc = TrainConfig(learning_rate=1e-2, total_steps=10, warmup_steps=1,
                         grad_accum=A, remat="block", optimizer="sgd")
        step = jax.jit(make_train_step_fn(cfg, tc))
        opt = make_optimizer(tc)[0](params)
        p2, _, m = step(params, opt, batch)
        outs[A] = (p2, float(m["loss"]))
    np.testing.assert_allclose(outs[1][1], outs[4][1], rtol=2e-5)
    for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-3)


def test_contribution_gate_changes_forward_and_is_identityish_at_init(key):
    cfg = smoke_variant(get_config("qwen2-1.5b"))
    cfg_g = cfg.replace(contribution_gate=True)
    params = tfm.init(cfg_g, key)
    batch = {k: jnp.asarray(v)
             for k, v in synthetic_token_batch(cfg, 2, 16).items()}
    x_g, _ = tfm.forward(params, cfg_g, batch, dtype=jnp.float32)
    # gate weight = 2*sigmoid(small) ~ 1 at init -> output close to ungated
    params_ng = {k: v for k, v in params.items() if k != "gate"}
    x_ng, _ = tfm.forward(params_ng, cfg, batch, dtype=jnp.float32)
    rel = float(jnp.mean(jnp.abs(x_g - x_ng)) / (jnp.mean(jnp.abs(x_ng)) + 1e-9))
    assert rel < 0.5                      # same ballpark at init
    # and the gate is trainable end-to-end
    loss_fn = lambda p: tfm.lm_loss(p, cfg_g, batch)[0]
    g = jax.grad(loss_fn)(params)
    gnorm = sum(float(jnp.sum(jnp.abs(l)))
                for l in jax.tree.leaves(g["gate"]))
    assert gnorm > 0


def test_fsdp_rules_shard_embed_dim():
    import jax as j
    from repro.distributed.sharding import make_rules
    mesh = j.make_mesh((1, 1), ("data", "model"))
    r_act = make_rules(get_config("olmo-1b"), mesh=mesh)
    r_par = make_rules(get_config("olmo-1b"), mesh=mesh, fsdp=True)
    assert r_act["embed"] is None
    assert r_par["embed"] == "data"
