"""Roofline derivation (deliverable g): reads results/dryrun/*.json and
computes the three roofline terms per (arch x shape x mesh):

  compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

cost_analysis numbers are PER DEVICE (post-GSPMD SPMD module), so
HLO_FLOPs = flops_per_device * chips; same for bytes/collectives — the
chips factor cancels and each term reduces to per-device / per-chip-rate.
Also reports MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, Optional

from repro.configs import INPUT_SHAPES, get_config

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
ICI_BW = 50e9            # B/s / link

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def count_params(cfg) -> Dict[str, float]:
    """Analytic parameter counts (total and active-per-token)."""
    d, L = cfg.d_model, cfg.num_layers
    dh = cfg.resolved_head_dim()
    emb = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    total = emb
    active = emb
    for kind in cfg.layer_kinds():
        layer = 0.0
        if kind in ("global", "local"):
            layer += d * dh * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)
        elif kind == "recurrent":
            w = cfg.rglru.lru_width or d
            layer += 2 * d * w + 2 * w * w + w * d
        elif kind == "ssm":
            s = cfg.ssm
            di = s.d_inner(d)
            layer += 2 * d * di + 2 * d * s.ngroups * s.state_dim \
                + d * s.num_heads(d) + di * d
        total += layer
        active += layer
    # FFN
    n_moe = 0 if cfg.moe is None else cfg.num_layers - cfg.first_k_dense
    n_dense = sum(1 for k in cfg.layer_kinds() if k != "ssm") - n_moe
    if cfg.d_ff:
        total += n_dense * 3 * d * cfg.d_ff
        active += n_dense * 3 * d * cfg.d_ff
    if cfg.moe is not None:
        m = cfg.moe
        total += n_moe * (3 * d * m.d_ff_expert * m.num_experts
                          + d * m.num_experts)
        active += n_moe * 3 * d * m.d_ff_expert * m.top_k
        if m.num_shared:
            sh = 3 * d * (m.d_ff_shared or m.d_ff_expert * m.num_shared)
            total += n_moe * sh
            active += n_moe * sh
    return {"total": total, "active": active}


def model_flops(cfg, shape) -> float:
    """6*N_active*D tokens (train) or 2*N_active*D (fwd-only)."""
    n = count_params(cfg)["active"]
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def analyze(rec: dict) -> Optional[dict]:
    if "error" in rec.get("cost", {}):
        return None
    cfg = get_config(rec["arch"])
    shape = INPUT_SHAPES[rec["shape"]]
    chips = rec["devices"]
    fl_dev = rec["cost"].get("flops", 0.0)
    by_dev = rec["cost"].get("bytes accessed", 0.0)
    coll_dev = rec["collectives"]["total_bytes"]
    t_comp = fl_dev / PEAK_FLOPS
    t_mem = by_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    mf_dev = mf / chips
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "step": rec["step_kind"], "chips": chips,
        "tag": rec.get("tag", ""), "opts": rec.get("opts", {}),
        "flops_dev": fl_dev, "bytes_dev": by_dev, "coll_dev": coll_dev,
        **{k: round(v * 1e3, 4) for k, v in
           (("compute_ms", t_comp), ("memory_ms", t_mem),
            ("collective_ms", t_coll))},
        "dominant": dominant.replace("_s", ""),
        "model_flops_dev": mf_dev,
        "useful_ratio": round(mf_dev / fl_dev, 3) if fl_dev else None,
        "hbm_gb": (rec.get("memory", {}).get("temp_size_in_bytes", 0)
                   + rec.get("memory", {}).get("argument_size_in_bytes", 0))
        / 1e9,
        "hbm_fit": rec.get("memory", {}).get("temp_size_in_bytes", 0)
        + rec.get("memory", {}).get("argument_size_in_bytes", 0) < 16e9,
        "collective_counts": rec["collectives"]["counts"],
    }


def load_all(dry_dir: str = DRYRUN_DIR):
    rows = []
    for path in sorted(glob.glob(os.path.join(dry_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        row = analyze(rec)
        if row:
            rows.append(row)
    return rows


def markdown_table(rows, mesh: str = "pod") -> str:
    """§Roofline table (single-pod per the assignment)."""
    hdr = ("| arch | shape | step | compute ms | memory ms | coll ms | "
           "dominant | useful | fits |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh or r.get("tag"):
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} "
            f"| {r['compute_ms']:.2f} | {r['memory_ms']:.2f} "
            f"| {r['collective_ms']:.2f} | **{r['dominant']}** "
            f"| {r['useful_ratio']} | {'y' if r['hbm_fit'] else 'NO'} |")
    return "\n".join(lines)


def perf_table(rows) -> str:
    """§Perf: tagged (optimized) runs vs their baselines."""
    base = {(r["arch"], r["shape"], r["mesh"]): r
            for r in rows if not r.get("tag")}
    lines = ["| arch | shape | tag | coll ms (base->opt) | memory ms | "
             "temp+args GB | fits |", "|" + "---|" * 7]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["tag"])):
        if not r.get("tag") or r["mesh"] != "pod":
            continue
        b = base.get((r["arch"], r["shape"], r["mesh"]))
        if not b:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['tag']} "
            f"| {b['collective_ms']:.0f} -> {r['collective_ms']:.0f} "
            f"| {b['memory_ms']:.0f} -> {r['memory_ms']:.0f} "
            f"| {b['hbm_gb']:.1f} -> {r['hbm_gb']:.1f} "
            f"| {'y' if r['hbm_fit'] else 'NO'} |")
    return "\n".join(lines)


def main():
    rows = load_all()
    print(markdown_table(rows))
    print()
    print(perf_table(rows))
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.json", "w") as f:
        json.dump(rows, f, indent=2)
    print(f"\n{len(rows)} combos analyzed -> results/roofline.json")
    return rows


if __name__ == "__main__":
    main()
