"""Ablation studies (paper §3: "rigorous experiments and ablation studies").

Isolates each Dom-ST ingredient on a fixed watershed set:
  A. full Dom-ST (pixcon + dynamic partition + multihead + P)
  B. - Pix-Con weighting (static raster partition, multihead, +P)
  C. - dynamic partitioning (pixcon weights applied, raster partition)
  D. - normalization in Pix-Con
  E. contribution gate on an LM arch (the generalized Pix-Con; DESIGN.md §5):
     train qwen2-smoke with/without cfg.contribution_gate on Zipf tokens.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_config, smoke_variant
from repro.configs.base import PixConConfig
from repro.core import domst
from repro.data import generate_all_watersheds, make_training_windows
from repro.data.pipeline import train_test_split
from repro.data.tokens import synthetic_token_batch
from repro.models import transformer as tfm
from repro.optim import make_optimizer


def _train_eval(cfg, w, iters=120, seed=0):
    tr, te = train_test_split(w)
    tc = TrainConfig(learning_rate=3e-3, total_steps=iters, warmup_steps=10)
    params = domst.init(cfg, jax.random.key(seed + w.watershed_id))
    step = domst.make_train_step(cfg, tc)
    opt = make_optimizer(tc)[0](params)
    rng = np.random.default_rng(seed)
    n = len(tr["discharge"])
    for _ in range(iters):
        sl = rng.integers(0, n, 64)
        params, opt, _ = step(params, opt,
                              {k: jnp.asarray(v[sl]) for k, v in tr.items()})
    te_j = {k: jnp.asarray(v) for k, v in te.items()}
    return float(domst.evaluate(params, cfg, te_j)["nse"])


def domst_variants(num_watersheds=5, days=250, iters=120) -> Dict[str, float]:
    base = get_config("domst")
    dc = base.domst
    variants = {
        "A_full_domst": base,
        "B_no_pixcon": base.replace(
            domst=dataclasses.replace(dc, use_pixcon=False)),
        "D_no_normalize": base.replace(
            domst=dataclasses.replace(
                dc, pixcon=PixConConfig(normalize=False))),
    }
    data = generate_all_watersheds(num_watersheds, num_days=days)
    windows = [make_training_windows(w) for w in data.values()]
    out = {}
    for name, cfg in variants.items():
        nses = [_train_eval(cfg, w, iters) for w in windows]
        out[name] = float(np.mean(nses))
    return out


def lm_gate_ablation(steps=40) -> Dict[str, float]:
    out = {}
    for gate in (False, True):
        cfg = smoke_variant(get_config("qwen2-1.5b")).replace(
            contribution_gate=gate)
        tc = TrainConfig(learning_rate=3e-3, total_steps=steps, warmup_steps=5)
        params = tfm.init(cfg, jax.random.key(0))
        opt_init, opt_update = make_optimizer(tc)
        opt = opt_init(params)

        @jax.jit
        def step(p, o, b):
            (loss, _), g = jax.value_and_grad(
                lambda q: tfm.lm_loss(q, cfg, b), has_aux=True)(p)
            p, o, _ = opt_update(p, g, o)
            return p, o, loss

        losses = []
        for i in range(steps):
            b = {k: jnp.asarray(v) for k, v in
                 synthetic_token_batch(cfg, 4, 32, seed=i).items()}
            params, opt, loss = step(params, opt, b)
            losses.append(float(loss))
        out["gate_on" if gate else "gate_off"] = losses[-1]
    return out


def main():
    t0 = time.perf_counter()
    res = {"domst": domst_variants(), "lm_gate": lm_gate_ablation(),
           }
    res["wall_s"] = round(time.perf_counter() - t0, 1)
    os.makedirs("results", exist_ok=True)
    with open("results/ablation_pixcon.json", "w") as f:
        json.dump(res, f, indent=2)
    print(json.dumps(res, indent=2))
    return res


if __name__ == "__main__":
    main()
