"""Input-pipeline benchmark (paper Fig. 2a): steps/sec with the synchronous
host loop vs the ShardedLoader's background prefetch.

For each drive path (Dom-ST stacked/IP-D and the smoke LM token stream)
the SAME engine and batch stream are driven twice — ``prefetch=0``
(host windowing + device_put on the step's critical path, the pre-PR-2
behavior) and ``prefetch=2`` (double-buffered background thread) — and
steps/sec are recorded to ``BENCH_PR2.json``:

    python -m benchmarks.loader_bench [--smoke] [--out BENCH_PR2.json]

``--smoke`` shrinks sizes for CI; the numbers are honest either way (on a
shared-core CPU container the overlap win is modest — the bench exists so
the trajectory is tracked, and so real hardware has a ready measurement).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax


def _steps_per_sec(engine, state, source, *, prefetch: int, num_steps: int,
                   start_step: int = 0):
    from repro.data.loader import ShardedLoader
    loader = ShardedLoader(source, engine, prefetch=prefetch,
                           start_step=start_step, num_steps=num_steps)
    n = 0
    t0 = time.perf_counter()
    for batch in loader:
        state, m = engine.step(state, batch)
        n += 1
    jax.block_until_ready(m["loss"])
    dt = time.perf_counter() - t0
    return state, n / dt


def bench_domst(*, num_watersheds: int, days: int, batch_size: int,
                epochs: int) -> dict:
    from repro.configs import TrainConfig, get_config
    from repro.core import domst
    from repro.data import generate_all_watersheds, make_training_windows
    from repro.data.pipeline import InputPipeline, StackedSource
    from repro.train import Engine

    cfg = get_config("domst")
    tc = TrainConfig(learning_rate=1e-3, total_steps=10_000, warmup_steps=50)
    data = generate_all_watersheds(num_watersheds, num_days=days)
    windows = [make_training_windows(w) for w in data.values()]
    ip = InputPipeline(windows, batch_size=batch_size, seed=0)
    source = StackedSource(ip)
    engine = Engine.for_domst(cfg, tc, stacked=True)
    state = engine.init_state(
        jax.random.key(0),
        domst.init_stacked(cfg, jax.random.key(0), len(windows)))
    n = epochs * source.steps_per_epoch
    # warmup epoch compiles the step and pages the windows in
    state, _ = _steps_per_sec(engine, state, source, prefetch=0,
                              num_steps=source.steps_per_epoch)
    # best-of-2 per mode, alternating, to damp scheduler noise on small hosts
    sync = pre = 0.0
    step0 = source.steps_per_epoch
    for _ in range(2):
        state, s = _steps_per_sec(engine, state, source, prefetch=0,
                                  num_steps=n, start_step=step0)
        state, p = _steps_per_sec(engine, state, source, prefetch=2,
                                  num_steps=n, start_step=step0 + n)
        sync, pre, step0 = max(sync, s), max(pre, p), step0 + 2 * n
    return {"path": "domst_stacked", "num_watersheds": num_watersheds,
            "batch_size": batch_size, "steps": n,
            "sync_steps_per_s": round(sync, 3),
            "prefetch_steps_per_s": round(pre, 3),
            "speedup": round(pre / sync, 3)}


def bench_lm(*, arch: str, batch_size: int, seq_len: int, steps: int) -> dict:
    from repro.configs import TrainConfig, get_config, smoke_variant
    from repro.data.tokens import TokenSource
    from repro.models import transformer as tfm
    from repro.train import Engine

    cfg = smoke_variant(get_config(arch))
    tc = TrainConfig(learning_rate=1e-3, total_steps=10_000,
                     warmup_steps=10, remat="block")
    engine = Engine.for_lm(cfg, tc)
    state = engine.init_state(jax.random.key(0), tfm.init(cfg, jax.random.key(0)))
    source = TokenSource(cfg, batch_size, seq_len, seed=0)
    state, _ = _steps_per_sec(engine, state, source, prefetch=0, num_steps=3)
    sync = pre = 0.0
    step0 = 3
    for _ in range(2):
        state, s = _steps_per_sec(engine, state, source, prefetch=0,
                                  num_steps=steps, start_step=step0)
        state, p = _steps_per_sec(engine, state, source, prefetch=2,
                                  num_steps=steps, start_step=step0 + steps)
        sync, pre, step0 = max(sync, s), max(pre, p), step0 + 2 * steps
    return {"path": "lm_smoke", "arch": cfg.name, "batch_size": batch_size,
            "seq_len": seq_len, "steps": steps,
            "sync_steps_per_s": round(sync, 3),
            "prefetch_steps_per_s": round(pre, 3),
            "speedup": round(pre / sync, 3)}


def run(*, smoke: bool = False) -> dict:
    if smoke:
        rows = [bench_domst(num_watersheds=3, days=160, batch_size=16,
                            epochs=2),
                bench_lm(arch="qwen2-1.5b", batch_size=4, seq_len=64,
                         steps=10)]
    else:
        rows = [bench_domst(num_watersheds=8, days=400, batch_size=32,
                            epochs=3),
                bench_lm(arch="qwen2-1.5b", batch_size=8, seq_len=128,
                         steps=30)]
    return {"bench": "loader_prefetch_vs_sync", "smoke": smoke,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(), "rows": rows}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI")
    ap.add_argument("--out", default="BENCH_PR2.json")
    args = ap.parse_args()
    res = run(smoke=args.smoke)
    for r in res["rows"]:
        print(f"{r['path']}: sync {r['sync_steps_per_s']} steps/s, "
              f"prefetch {r['prefetch_steps_per_s']} steps/s "
              f"({r['speedup']}x)", flush=True)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
        f.write("\n")
    print("wrote", args.out)


if __name__ == "__main__":
    main()
