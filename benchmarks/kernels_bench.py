"""Kernel micro-benchmarks: fused Pallas (interpret) path vs pure-jnp
oracle, per-call microseconds.  On CPU the interpret path is SLOWER (it
executes the kernel body in Python) — the number that matters here is the
oracle column (the XLA-fused baseline the TPU kernel must beat) plus the
allclose check; wall-time wins are TPU-only.

``rows()`` feeds the ``kernel_*`` CSV listing in ``benchmarks.run``.  The
paged-attention rows are ALSO written to ``BENCH_PR6.json`` for the
regression gate:

    python -m benchmarks.kernels_bench [--smoke] [--out BENCH_PR6.json]

One row per serve shape (``paged_attn_decode`` / ``_verify`` /
``_prefill``): fused-kernel vs lax-fallback (gather_pages +
attend_masked) tokens/sec and their ``fused_speedup`` quotient.  On this
CPU container the fused column runs the Pallas interpreter, so
``fused_speedup`` < 1 by construction — the ratio is gated (wide
tolerance) to track the trajectory, and flips to the paper's >1x claim
only on a real TPU backend.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Callable

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def time_call(fn: Callable, *args, iters: int = 20) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def rows():
    rng = np.random.default_rng(0)
    out = []

    from repro.kernels.pixcon.ops import pixcon_gate
    from repro.kernels.pixcon.ref import pixcon_gate_ref
    B, T, P, F, H = 32, 30, 64, 4, 32
    a = (jnp.asarray(rng.normal(0, 1, (B, T, P)).astype("float32")),
         jnp.asarray(rng.normal(0, 1, (B, P, F)).astype("float32")),
         jnp.asarray(rng.normal(0, .5, (F, H)).astype("float32")),
         jnp.zeros(H), jnp.asarray(rng.normal(0, .5, H).astype("float32")),
         jnp.zeros(()))
    ref = jax.jit(pixcon_gate_ref)
    err = float(jnp.max(jnp.abs(pixcon_gate(*a) - ref(*a))))
    out.append(("pixcon_pallas_interp", time_call(pixcon_gate, *a),
                f"allclose_err={err:.1e}"))
    out.append(("pixcon_jnp_oracle", time_call(ref, *a), "xla_fused_baseline"))

    from repro.kernels.conv1d.ops import causal_conv1d
    from repro.kernels.conv1d.ref import causal_conv1d_ref
    a = (jnp.asarray(rng.normal(0, 1, (8, 512, 256)).astype("float32")),
         jnp.asarray(rng.normal(0, .5, (4, 256)).astype("float32")),
         jnp.zeros(256))
    f1 = lambda *x: causal_conv1d(*x, activation="silu")
    f2 = jax.jit(lambda *x: causal_conv1d_ref(*x, activation="silu"))
    err = float(jnp.max(jnp.abs(f1(*a) - f2(*a))))
    out.append(("conv1d_pallas_interp", time_call(f1, *a),
                f"allclose_err={err:.1e}"))
    out.append(("conv1d_jnp_oracle", time_call(f2, *a), "xla_fused_baseline"))

    from repro.kernels.lstm_cell.ops import lstm_cell_fused
    from repro.kernels.lstm_cell.ref import lstm_cell_ref
    B, D, H = 64, 128, 256
    a = (jnp.asarray(rng.normal(0, 1, (B, D)).astype("float32")),
         jnp.asarray(rng.normal(0, 1, (B, H)).astype("float32")),
         jnp.asarray(rng.normal(0, 1, (B, H)).astype("float32")),
         jnp.asarray(rng.normal(0, .2, (D, 4, H)).astype("float32")),
         jnp.asarray(rng.normal(0, .2, (H, 4, H)).astype("float32")),
         jnp.zeros((4, H)))
    ref = jax.jit(lstm_cell_ref)
    err = float(max(jnp.max(jnp.abs(x - y))
                    for x, y in zip(lstm_cell_fused(*a), ref(*a))))
    out.append(("lstm_cell_pallas_interp", time_call(lstm_cell_fused, *a),
                f"allclose_err={err:.1e}"))
    out.append(("lstm_cell_jnp_oracle", time_call(ref, *a),
                "xla_fused_baseline"))

    from repro.kernels.ssd_chunk.ops import ssd_chunk_fused
    from repro.kernels.ssd_chunk.ref import ssd_chunk_ref
    Bsz, nc, Q, H, N, P = 2, 4, 64, 4, 32, 16
    Cc = jnp.asarray(rng.normal(0, 1, (Bsz, nc, Q, H, N)).astype("float32"))
    Bc = jnp.asarray(rng.normal(0, 1, (Bsz, nc, Q, H, N)).astype("float32"))
    xdt = jnp.asarray(rng.normal(0, 1, (Bsz, nc, Q, H, P)).astype("float32"))
    dA = jnp.asarray(np.cumsum(-rng.uniform(0.01, 0.3, (Bsz, nc, H, Q)), -1)
                     .astype("float32"))
    to_k = lambda t: t.transpose(0, 1, 3, 2, 4).reshape(Bsz * nc, H, Q, -1)
    ref_fn = jax.jit(lambda c, b, x, d: ssd_chunk_ref(
        to_k(c), to_k(b), to_k(x), d.reshape(Bsz * nc, H, Q)))
    y1, s1 = ssd_chunk_fused(Cc, Bc, xdt, dA)
    y2, s2 = ref_fn(Cc, Bc, xdt, dA)
    err = float(jnp.max(jnp.abs(
        y1.transpose(0, 1, 3, 2, 4).reshape(Bsz * nc, H, Q, P) - y2)))
    out.append(("ssd_chunk_pallas_interp",
                time_call(ssd_chunk_fused, Cc, Bc, xdt, dA),
                f"allclose_err={err:.1e}"))
    out.append(("ssd_chunk_jnp_oracle", time_call(ref_fn, Cc, Bc, xdt, dA),
                "xla_fused_baseline"))

    from repro.kernels.local_attn.ops import local_attention_fused
    from repro.kernels.local_attn.ref import local_attention_ref
    q = jnp.asarray(rng.normal(0, 1, (2, 256, 4, 64)).astype("float32"))
    k = jnp.asarray(rng.normal(0, 1, (2, 256, 2, 64)).astype("float32"))
    v = jnp.asarray(rng.normal(0, 1, (2, 256, 2, 64)).astype("float32"))
    f1 = lambda *x: local_attention_fused(*x, window=64, block_q=64)
    f2 = jax.jit(lambda *x: local_attention_ref(*x, window=64))
    err = float(jnp.max(jnp.abs(f1(q, k, v) - f2(q, k, v))))
    out.append(("local_attn_pallas_interp", time_call(f1, q, k, v),
                f"allclose_err={err:.1e}"))
    out.append(("local_attn_jnp_oracle", time_call(f2, q, k, v),
                "xla_fused_baseline"))

    from repro.kernels.paged_attn.ops import paged_attention_fused
    from repro.kernels.paged_attn.ref import paged_attention_ref
    a = _paged_args(rng, batch=4, q_len=4, hq=4, hkv=2, head_dim=64,
                    page_size=16, pages_per_slot=4)
    f1 = lambda *x: paged_attention_fused(*x)
    B, T, Hq, D = a[0].shape
    Hkv = a[1].shape[2]
    f2 = jax.jit(lambda q, k, v, p, r, qp: paged_attention_ref(
        q.reshape(B, T, Hkv, Hq // Hkv, D), k, v, p, r, qp
    ).reshape(B, T, Hq, D))
    err = float(jnp.max(jnp.abs(f1(*a) - f2(*a))))
    out.append(("paged_attn_pallas_interp", time_call(f1, *a, iters=5),
                f"allclose_err={err:.1e}"))
    out.append(("paged_attn_jnp_oracle", time_call(f2, *a),
                "xla_fused_baseline"))
    return out


def _paged_args(rng, *, batch, q_len, hq, hkv, head_dim, page_size,
                pages_per_slot):
    """A fully warmed paged workload: every slot owns ``pages_per_slot``
    shuffled pages with a ragged tail page, queries sit at the live end."""
    P = batch * pages_per_slot + 2                  # +2 unassigned spares
    perm = rng.permutation(P - 2)
    rows = np.asarray(perm).reshape(batch, pages_per_slot).astype(np.int32)
    pos = np.full((P, page_size), -1, np.int32)
    lens = [pages_per_slot * page_size - (b % page_size)
            for b in range(batch)]                  # ragged per-slot lengths
    for b in range(batch):
        for j in range(pages_per_slot):
            fill = min(page_size, lens[b] - j * page_size)
            if fill > 0:
                pos[rows[b, j], :fill] = np.arange(j * page_size,
                                                   j * page_size + fill)
    qpos = np.asarray([[lens[b] - 1 + t for t in range(q_len)]
                       for b in range(batch)], np.int32)
    q = jnp.asarray(rng.normal(0, 1, (batch, q_len, hq, head_dim)),
                    jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (P, page_size, hkv, head_dim)),
                    jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (P, page_size, hkv, head_dim)),
                    jnp.float32)
    return (q, k, v, jnp.asarray(pos), jnp.asarray(rows), jnp.asarray(qpos))


def paged_rows(*, smoke: bool = False) -> list:
    """The BENCH_PR6 rows: fused kernel vs the lax fallback it replaces,
    across the three serve shapes (decode / speculative verify / chunked
    prefill)."""
    import types

    from repro.kernels.paged_attn.ops import paged_attention_fused
    from repro.models.attention import (
        PagedKVCache, attend_masked, gather_pages,
    )

    if smoke:
        wl = dict(batch=4, hq=4, hkv=2, head_dim=64, page_size=16,
                  pages_per_slot=4)
        shapes = [("paged_attn_decode", 1), ("paged_attn_verify", 4),
                  ("paged_attn_prefill", 16)]
    else:
        wl = dict(batch=8, hq=8, hkv=2, head_dim=64, page_size=16,
                  pages_per_slot=16)
        shapes = [("paged_attn_decode", 1), ("paged_attn_verify", 5),
                  ("paged_attn_prefill", 64)]
    cfg = types.SimpleNamespace(attn_softcap=0.0)

    def lax_fn(q, k, v, p, rows, qpos):
        k_all, v_all, kp = gather_pages(PagedKVCache(k, v, p), rows)
        return attend_masked(cfg, q, k_all, v_all, kp, qpos)

    lax_jit = jax.jit(lax_fn)
    rng = np.random.default_rng(0)
    out = []
    for path, q_len in shapes:
        a = _paged_args(rng, q_len=q_len, **wl)
        err = float(jnp.max(jnp.abs(paged_attention_fused(*a)
                                    - lax_jit(*a))))
        fused_us = time_call(paged_attention_fused, *a, iters=5)
        lax_us = time_call(lax_jit, *a)
        tokens = wl["batch"] * q_len
        out.append({"path": path, "q_len": q_len, **wl,
                    "fused_tok_per_s": round(tokens / (fused_us * 1e-6), 1),
                    "lax_tok_per_s": round(tokens / (lax_us * 1e-6), 1),
                    "fused_speedup": round(lax_us / fused_us, 4),
                    "allclose_err": float(f"{err:.1e}")})
    return out


def run(*, smoke: bool = False) -> dict:
    from repro.kernels.common import use_interpret
    return {"bench": "paged_attn_kernel", "smoke": smoke,
            "backend": jax.default_backend(),
            "interpret": use_interpret(),
            "device_count": len(jax.devices()),
            "rows": paged_rows(smoke=smoke)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small sizes for CI")
    ap.add_argument("--out", default="BENCH_PR6.json")
    args = ap.parse_args()
    res = run(smoke=args.smoke)
    for r in res["rows"]:
        print(json.dumps(r), flush=True)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
        f.write("\n")
    print("wrote", args.out)


if __name__ == "__main__":
    main()
