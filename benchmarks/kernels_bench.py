"""Kernel micro-benchmarks: fused Pallas (interpret) path vs pure-jnp
oracle, per-call microseconds.  On CPU the interpret path is SLOWER (it
executes the kernel body in Python) — the number that matters here is the
oracle column (the XLA-fused baseline the TPU kernel must beat) plus the
allclose check; wall-time wins are TPU-only.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


def time_call(fn: Callable, *args, iters: int = 20) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def rows():
    rng = np.random.default_rng(0)
    out = []

    from repro.kernels.pixcon.ops import pixcon_gate
    from repro.kernels.pixcon.ref import pixcon_gate_ref
    B, T, P, F, H = 32, 30, 64, 4, 32
    a = (jnp.asarray(rng.normal(0, 1, (B, T, P)).astype("float32")),
         jnp.asarray(rng.normal(0, 1, (B, P, F)).astype("float32")),
         jnp.asarray(rng.normal(0, .5, (F, H)).astype("float32")),
         jnp.zeros(H), jnp.asarray(rng.normal(0, .5, H).astype("float32")),
         jnp.zeros(()))
    ref = jax.jit(pixcon_gate_ref)
    err = float(jnp.max(jnp.abs(pixcon_gate(*a) - ref(*a))))
    out.append(("pixcon_pallas_interp", time_call(pixcon_gate, *a),
                f"allclose_err={err:.1e}"))
    out.append(("pixcon_jnp_oracle", time_call(ref, *a), "xla_fused_baseline"))

    from repro.kernels.conv1d.ops import causal_conv1d
    from repro.kernels.conv1d.ref import causal_conv1d_ref
    a = (jnp.asarray(rng.normal(0, 1, (8, 512, 256)).astype("float32")),
         jnp.asarray(rng.normal(0, .5, (4, 256)).astype("float32")),
         jnp.zeros(256))
    f1 = lambda *x: causal_conv1d(*x, activation="silu")
    f2 = jax.jit(lambda *x: causal_conv1d_ref(*x, activation="silu"))
    err = float(jnp.max(jnp.abs(f1(*a) - f2(*a))))
    out.append(("conv1d_pallas_interp", time_call(f1, *a),
                f"allclose_err={err:.1e}"))
    out.append(("conv1d_jnp_oracle", time_call(f2, *a), "xla_fused_baseline"))

    from repro.kernels.lstm_cell.ops import lstm_cell_fused
    from repro.kernels.lstm_cell.ref import lstm_cell_ref
    B, D, H = 64, 128, 256
    a = (jnp.asarray(rng.normal(0, 1, (B, D)).astype("float32")),
         jnp.asarray(rng.normal(0, 1, (B, H)).astype("float32")),
         jnp.asarray(rng.normal(0, 1, (B, H)).astype("float32")),
         jnp.asarray(rng.normal(0, .2, (D, 4, H)).astype("float32")),
         jnp.asarray(rng.normal(0, .2, (H, 4, H)).astype("float32")),
         jnp.zeros((4, H)))
    ref = jax.jit(lstm_cell_ref)
    err = float(max(jnp.max(jnp.abs(x - y))
                    for x, y in zip(lstm_cell_fused(*a), ref(*a))))
    out.append(("lstm_cell_pallas_interp", time_call(lstm_cell_fused, *a),
                f"allclose_err={err:.1e}"))
    out.append(("lstm_cell_jnp_oracle", time_call(ref, *a),
                "xla_fused_baseline"))

    from repro.kernels.ssd_chunk.ops import ssd_chunk_fused
    from repro.kernels.ssd_chunk.ref import ssd_chunk_ref
    Bsz, nc, Q, H, N, P = 2, 4, 64, 4, 32, 16
    Cc = jnp.asarray(rng.normal(0, 1, (Bsz, nc, Q, H, N)).astype("float32"))
    Bc = jnp.asarray(rng.normal(0, 1, (Bsz, nc, Q, H, N)).astype("float32"))
    xdt = jnp.asarray(rng.normal(0, 1, (Bsz, nc, Q, H, P)).astype("float32"))
    dA = jnp.asarray(np.cumsum(-rng.uniform(0.01, 0.3, (Bsz, nc, H, Q)), -1)
                     .astype("float32"))
    to_k = lambda t: t.transpose(0, 1, 3, 2, 4).reshape(Bsz * nc, H, Q, -1)
    ref_fn = jax.jit(lambda c, b, x, d: ssd_chunk_ref(
        to_k(c), to_k(b), to_k(x), d.reshape(Bsz * nc, H, Q)))
    y1, s1 = ssd_chunk_fused(Cc, Bc, xdt, dA)
    y2, s2 = ref_fn(Cc, Bc, xdt, dA)
    err = float(jnp.max(jnp.abs(
        y1.transpose(0, 1, 3, 2, 4).reshape(Bsz * nc, H, Q, P) - y2)))
    out.append(("ssd_chunk_pallas_interp",
                time_call(ssd_chunk_fused, Cc, Bc, xdt, dA),
                f"allclose_err={err:.1e}"))
    out.append(("ssd_chunk_jnp_oracle", time_call(ref_fn, Cc, Bc, xdt, dA),
                "xla_fused_baseline"))

    from repro.kernels.local_attn.ops import local_attention_fused
    from repro.kernels.local_attn.ref import local_attention_ref
    q = jnp.asarray(rng.normal(0, 1, (2, 256, 4, 64)).astype("float32"))
    k = jnp.asarray(rng.normal(0, 1, (2, 256, 2, 64)).astype("float32"))
    v = jnp.asarray(rng.normal(0, 1, (2, 256, 2, 64)).astype("float32"))
    f1 = lambda *x: local_attention_fused(*x, window=64, block_q=64)
    f2 = jax.jit(lambda *x: local_attention_ref(*x, window=64))
    err = float(jnp.max(jnp.abs(f1(q, k, v) - f2(q, k, v))))
    out.append(("local_attn_pallas_interp", time_call(f1, q, k, v),
                f"allclose_err={err:.1e}"))
    out.append(("local_attn_jnp_oracle", time_call(f2, q, k, v),
                "xla_fused_baseline"))
    return out
