"""Serving benchmark: prefill vs decode throughput through the sharded
inference engine, continuous batching vs sequential requests, paged vs
contiguous KV cache, and chunked-prefill admission latency.

Rows recorded to ``BENCH_PR3.json``:

  * ``serve_prefill_vs_decode``     — tokens/sec of the two jitted steps;
  * ``serve_batched_vs_sequential`` — the same queue at slots=1 vs slots=N;
  * ``serve_paged_vs_contiguous``   — the same queue through the contiguous
    slot-major cache and through a page pool sized to live tokens: tok/s
    plus the KV-cache bytes each layout allocates (the paged pool is
    decoupled from ``slots * max_len``);
  * ``serve_admission_latency``     — a long prompt admitted while a
    victim request decodes: worst inter-token stall the victim sees with
    whole-prompt prefill vs chunked prefill (``stats["max_decode_gap_s"]``);
  * ``serve_domst_forecast``        — the Dom-ST rollout workload.

The SPECULATIVE row is recorded to ``BENCH_PR5.json`` (its own baseline
file so the PR-5 gate evolves independently):

  * ``serve_speculative`` — a repetitive-prompt queue served at
    ``spec_k=0`` (baseline) and with both drafters: tokens/sec, accepted
    tokens per fused decode step (the losslessness means every number
    describes the SAME output streams), for the checkpoint-free ngram
    drafter and a self-draft model drafter (acceptance upper bound).

The PREFIX-CACHE / PREEMPTION rows are recorded to ``BENCH_PR7.json``
(again a separate baseline so the PR-7 gate evolves independently):

  * ``serve_prefix_cache``     — a shared-system-prompt queue (75%% of
    every prompt is a common prefix) served cold vs through the
    refcounted radix cache: mean TTFT both ways, ``ttft_speedup``, and
    the %% of prefill tokens skipped.  Streams asserted bit-identical;
  * ``serve_preemption_burst`` — a burst queue against a page pool that
    holds one resident request: admission-latency (TTFT) percentiles
    with defer-only vs page-aware preemption, plus how many admissions
    each policy deferred.  Streams asserted identical.

The MIXED-SAMPLING row is recorded to ``BENCH_PR8.json`` (its own
baseline so the PR-8 gate evolves independently):

  * ``serve_mixed_sampling`` — one queue served all-greedy, with half
    the requests sampled (heterogeneous per-request temperature/top-k/
    top-p/penalty/seed in the same fused batch), and sampled with
    speculation on: tok/s each way plus ``sampling_overhead_ratio``
    (mixed/greedy).  Greedy rows asserted bit-identical to the
    all-greedy leg; speculation asserted stream-lossless under sampling.

The HOST-TIER row is recorded to ``BENCH_PR9.json`` (its own baseline so
the PR-9 gate evolves independently):

  * ``serve_host_tier_sweep`` — a forced-spill queue (alternating
    shared-prefix families on a pool that fits one request) swept over
    host-cache byte budgets, 0 included: %% of prompt prefill skipped,
    host hit/restore/spill counts and warm TTFT per size, plus
    ``host_ttft_speedup`` (no-host / largest budget — the gated ratio).
    Streams asserted bit-identical across every size.

    python -m benchmarks.serve_bench [--smoke] [--out BENCH_PR3.json] \
        [--spec-out BENCH_PR5.json] [--pr7-out BENCH_PR7.json] \
        [--pr8-out BENCH_PR8.json] [--pr9-out BENCH_PR9.json]

``--smoke`` shrinks sizes for CI; the numbers are honest either way (on a
shared-core CPU container the batching win is modest — the bench exists
so the trajectory is tracked, and so real hardware has a ready
measurement).  ``device_count`` / ``mesh_shape`` record what the engines
actually ran on (CI forces 8 host devices via XLA_FLAGS).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np


def _bench_mesh():
    """The mesh every bench engine runs on: all visible devices on the
    data axis.  The engines used to fall back to their default 1x1 host
    mesh while the result doc claimed ``device_count`` devices, so the
    regression gate compared across different effective meshes; building
    one mesh here and threading it through every engine (drafters
    included) makes the recorded ``mesh_shape`` the truth."""
    return jax.make_mesh((len(jax.devices()), 1), ("data", "model"))


def _paged_attn_path() -> str:
    from repro.kernels.common import use_paged_attn_kernel
    return "fused" if use_paged_attn_kernel() else "lax"


def _make_requests(cfg, n, prompt_len, gen, seed=0):
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    return [Request(rid=i, max_new=gen,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        prompt_len).astype(np.int32))
            for i in range(n)]


def _cache_bytes(state) -> int:
    return int(sum(x.nbytes for x in jax.tree.leaves(state.cache)))


def _run_queue(cfg, params_key, *, slots, requests, prompt_len, gen,
               max_len=None, repeats=2, **engine_kw):
    """Serve the queue ``repeats`` times through one warmed-up engine and
    keep the best rates (loader_bench-style best-of-N: single-pass
    timings on a shared-core container swing too much to gate on).
    Returns ({prefill_tok_per_s, decode_tok_per_s}, best wall s, state)."""
    from repro.models import transformer as tfm
    from repro.serve import InferenceEngine, Scheduler

    engine = InferenceEngine(cfg, slots=slots,
                             max_len=max_len or (prompt_len + gen),
                             **engine_kw)
    state = engine.init_state(tfm.init(cfg, jax.random.key(params_key)))
    sched = Scheduler(engine, state)
    sched.run(_make_requests(cfg, slots, prompt_len, gen))    # compile warmup
    state = sched.state
    rates = {"prefill_tok_per_s": 0.0, "decode_tok_per_s": 0.0}
    wall = float("inf")
    for _ in range(repeats):
        sched = Scheduler(engine, state)
        t0 = time.perf_counter()
        out = sched.run(_make_requests(cfg, requests, prompt_len, gen))
        wall = min(wall, time.perf_counter() - t0)
        state = sched.state
        assert sum(len(g) for g in out.values()) == requests * gen
        st = sched.stats
        rates["prefill_tok_per_s"] = max(
            rates["prefill_tok_per_s"],
            st["prefill_tokens"] / max(st["prefill_s"], 1e-9))
        rates["decode_tok_per_s"] = max(
            rates["decode_tok_per_s"],
            st["decode_tokens"] / max(st["decode_s"], 1e-9))
    return rates, wall, state


def bench_lm(*, arch: str, slots: int, requests: int, prompt_len: int,
             gen: int, mesh=None) -> list:
    from repro.configs import get_config, smoke_variant

    cfg = smoke_variant(get_config(arch))
    rates, batched_s, _ = _run_queue(cfg, 0, slots=slots, requests=requests,
                                     prompt_len=prompt_len, gen=gen,
                                     mesh=mesh)
    _, seq_s, _ = _run_queue(cfg, 0, slots=1, requests=requests,
                             prompt_len=prompt_len, gen=gen, mesh=mesh)
    tokens = requests * gen
    return [
        {"path": "serve_prefill_vs_decode", "arch": cfg.name, "slots": slots,
         "requests": requests, "prompt_len": prompt_len, "gen": gen,
         "prefill_tok_per_s": round(rates["prefill_tok_per_s"], 1),
         "decode_tok_per_s": round(rates["decode_tok_per_s"], 1)},
        {"path": "serve_batched_vs_sequential", "arch": cfg.name,
         "slots": slots, "requests": requests, "gen": gen,
         "batched_tok_per_s": round(tokens / batched_s, 1),
         "sequential_tok_per_s": round(tokens / seq_s, 1),
         "speedup": round(seq_s / batched_s, 3)},
    ]


def bench_paged(*, arch: str, slots: int, requests: int, prompt_len: int,
                gen: int, page_size: int, mesh=None) -> dict:
    """Same queue, contiguous vs paged cache.  ``max_len`` is provisioned
    4x beyond what the queue needs (a serving config sized for its worst
    case); the paged pool is sized to the tokens actually live, so the
    memory row shows the decoupling, and the token streams still match."""
    from repro.configs import get_config, smoke_variant

    cfg = smoke_variant(get_config(arch))
    max_len = 4 * (prompt_len + gen)
    live_pages = slots * (-(-(prompt_len + gen) // page_size))
    _, contig_s, cstate = _run_queue(
        cfg, 0, slots=slots, requests=requests, prompt_len=prompt_len,
        gen=gen, max_len=max_len, mesh=mesh)
    _, paged_s, pstate = _run_queue(
        cfg, 0, slots=slots, requests=requests, prompt_len=prompt_len,
        gen=gen, max_len=max_len, paged=True, page_size=page_size,
        num_pages=live_pages, mesh=mesh)
    tokens = requests * gen
    cb, pb = _cache_bytes(cstate), _cache_bytes(pstate)
    return {"path": "serve_paged_vs_contiguous", "arch": cfg.name,
            "slots": slots, "requests": requests, "prompt_len": prompt_len,
            "gen": gen, "max_len": max_len, "page_size": page_size,
            "num_pages": live_pages, "paged_attn_path": _paged_attn_path(),
            "contiguous_tok_per_s": round(tokens / contig_s, 1),
            "paged_tok_per_s": round(tokens / paged_s, 1),
            "contiguous_cache_mib": round(cb / 2**20, 3),
            "paged_cache_mib": round(pb / 2**20, 3),
            "cache_mem_ratio": round(cb / max(pb, 1), 3)}


def bench_admission(*, arch: str, long_prompt: int, chunk: int,
                    gen: int, mesh=None) -> dict:
    """Worst decode stall while a long prompt is admitted mid-stream.

    A victim request streams tokens in one slot; a short request briefly
    holds the other, and when it finishes, a queued ``long_prompt``-token
    request is admitted into the freed slot while the victim is still
    decoding.  Whole-prompt prefill stalls the victim for the entire
    prefill; chunked prefill bounds each stall to one chunk.
    ``stats["max_decode_gap_s"]`` is the victim's worst inter-token gap."""
    from repro.configs import get_config, smoke_variant
    from repro.models import transformer as tfm
    from repro.serve import InferenceEngine, Request, Scheduler

    cfg = smoke_variant(get_config(arch))
    max_len = long_prompt + gen + 4

    def run(prefill_chunk):
        engine = InferenceEngine(cfg, slots=2, max_len=max_len, paged=True,
                                 page_size=chunk, mesh=mesh,
                                 prefill_chunk=prefill_chunk)
        state = engine.init_state(tfm.init(cfg, jax.random.key(0)))
        rng = np.random.default_rng(0)
        mk = lambda rid, n, g: Request(
            rid=rid, max_new=g,
            prompt=rng.integers(0, cfg.vocab_size, n).astype(np.int32))
        queue = lambda base: [mk(base, 4, gen),         # the victim stream
                              mk(base + 1, 4, 2),       # frees its slot fast
                              mk(base + 2, long_prompt, 2)]  # admitted mid-stream
        sched = Scheduler(engine, state)                # compile warmup
        sched.run(queue(100))
        stalls, gap_p99s = [], []
        for rep in range(2):                            # best-of-2 (CPU noise)
            sched = Scheduler(engine, sched.state)
            sched.run(queue(10 * rep))
            stalls.append(sched.stats["max_decode_gap_s"])
            # the registry's decode-gap histogram: the distribution tail,
            # not just the worst single stall
            gap_p99s.append(sched.decode_gaps.quantile(99))
        return min(stalls), min(gap_p99s)

    whole, whole_p99 = run(0)
    chunked, chunked_p99 = run(chunk)
    return {"path": "serve_admission_latency", "arch": cfg.name,
            "long_prompt": long_prompt, "prefill_chunk": chunk, "gen": gen,
            "whole_prefill_stall_s": round(whole, 4),
            "chunked_prefill_stall_s": round(chunked, 4),
            "whole_decode_gap_p99_s": round(whole_p99, 4),
            "chunked_decode_gap_p99_s": round(chunked_p99, 4),
            "stall_ratio": round(whole / max(chunked, 1e-9), 3)}


def bench_speculative(*, arch: str, slots: int, requests: int,
                      prompt_len: int, gen: int, spec_k: int,
                      page_size: int, motif: int = 4, mesh=None) -> dict:
    """Speculative decoding vs the fused one-token baseline.

    The queue is REPETITIVE — each prompt tiles a short random motif —
    because that is the workload speculation exists for: the ngram
    drafter proposes the motif's continuation from the prompt itself,
    and greedy decode output (which the context accumulates) gives it
    recurring n-grams to mine as generation proceeds.  The model-drafter
    leg self-drafts with the target's own params: its acceptance is the
    mechanical upper bound, so ``model_accepted_per_step`` close to
    ``spec_k + 1`` certifies the verify/rollback path, while the ngram
    leg shows what a checkpoint-free drafter earns on this traffic.
    All three legs emit bit-identical streams (asserted)."""
    from repro.configs import get_config, smoke_variant
    from repro.models import transformer as tfm
    from repro.serve import (
        InferenceEngine, ModelDrafter, NgramDrafter, Request, Scheduler,
    )

    cfg = smoke_variant(get_config(arch))
    max_len = prompt_len + gen
    rng = np.random.default_rng(0)
    motifs = [rng.integers(0, cfg.vocab_size, motif).astype(np.int32)
              for _ in range(requests)]

    def queue():
        return [Request(rid=i, max_new=gen,
                        prompt=np.tile(motifs[i],
                                       -(-prompt_len // motif))[:prompt_len])
                for i in range(requests)]

    def run(spec_k_, drafter):
        engine = InferenceEngine(cfg, slots=slots, max_len=max_len,
                                 paged=True, page_size=page_size, mesh=mesh)
        state = engine.init_state(tfm.init(cfg, jax.random.key(0)))
        sched = Scheduler(engine, state, spec_k=spec_k_, drafter=drafter)
        sched.run(queue())                          # compile warmup
        best = {"tok_per_s": 0.0, "accepted_per_step": 0.0}
        out = None
        for _ in range(2):                          # best-of-2 (CPU noise)
            sched = Scheduler(engine, sched.state, spec_k=spec_k_,
                              drafter=drafter)
            t0 = time.perf_counter()
            out = sched.run(queue())
            wall = time.perf_counter() - t0
            st = sched.stats
            best["tok_per_s"] = max(best["tok_per_s"],
                                    requests * gen / wall)
            best["accepted_per_step"] = max(
                best["accepted_per_step"],
                st["decode_tokens"] / max(st["decode_slot_steps"], 1))
        return best, out

    base, ref = run(0, None)
    ngram, out_n = run(spec_k, NgramDrafter())
    model_drafter = ModelDrafter(
        cfg, params=tfm.init(cfg, jax.random.key(0)), slots=slots,
        max_len=max_len + spec_k, page_size=page_size, mesh=mesh)
    model, out_m = run(spec_k, model_drafter)
    assert out_n == ref and out_m == ref, "speculation changed the streams"
    return {"path": "serve_speculative", "arch": cfg.name, "slots": slots,
            "requests": requests, "prompt_len": prompt_len, "gen": gen,
            "spec_k": spec_k, "page_size": page_size,
            "paged_attn_path": _paged_attn_path(),
            "baseline_tok_per_s": round(base["tok_per_s"], 1),
            "ngram_tok_per_s": round(ngram["tok_per_s"], 1),
            "model_tok_per_s": round(model["tok_per_s"], 1),
            "ngram_accepted_per_step": round(ngram["accepted_per_step"], 3),
            "model_accepted_per_step": round(model["accepted_per_step"], 3),
            "ngram_speedup": round(
                ngram["tok_per_s"] / max(base["tok_per_s"], 1e-9), 3),
            "model_speedup": round(
                model["tok_per_s"] / max(base["tok_per_s"], 1e-9), 3)}


def bench_prefix_cache(*, arch: str, prompt_len: int, shared: int, gen: int,
                       page_size: int, requests: int, chunk: int,
                       mesh=None) -> dict:
    """Shared-prefix workload (PR 7): every request's prompt opens with the
    same ``shared`` tokens (a system prompt).  One persistent scheduler
    serves the queue one request per run, so ``sched.ttft`` isolates each
    request's time-to-first-token; the prefix-cache leg maps the shared
    run by refcount bump and resumes prefill at the divergence point,
    while the cold leg re-prefills everything.  Streams are asserted
    bit-identical — the speedup buys latency, not different tokens."""
    from repro.configs import get_config, smoke_variant
    from repro.models import transformer as tfm
    from repro.serve import InferenceEngine, Request, Scheduler

    cfg = smoke_variant(get_config(arch))
    max_len = prompt_len + gen
    pre = np.random.default_rng(0).integers(
        0, cfg.vocab_size, shared).astype(np.int32)

    def mk(rid):
        tail = np.random.default_rng(100 + rid).integers(
            0, cfg.vocab_size, prompt_len - shared).astype(np.int32)
        return Request(rid=rid, max_new=gen,
                       prompt=np.concatenate([pre, tail]))

    def leg(prefix_cache):
        engine = InferenceEngine(cfg, slots=1, max_len=max_len, paged=True,
                                 page_size=page_size, prefill_chunk=chunk,
                                 mesh=mesh)
        state = engine.init_state(tfm.init(cfg, jax.random.key(0)))
        sched = Scheduler(engine, state, prefix_cache=prefix_cache)
        sched.run([mk(900)])            # compile warmup (cold path)
        sched.run([mk(901)])            # warm path: hits when caching
        ttfts, streams, hit_tokens = [], {}, 0
        for rid in range(requests):
            streams[rid] = sched.run([mk(rid)])[rid]
            ttfts.append(sched.ttft[rid])
            hit_tokens += sched.stats["prefix_hit_tokens"]
        return float(np.mean(ttfts)), streams, hit_tokens

    cold_ttft, cold_streams, _ = leg(False)
    warm_ttft, warm_streams, hits = leg(True)
    assert warm_streams == cold_streams, "prefix cache changed the streams"
    return {"path": "serve_prefix_cache", "arch": cfg.name,
            "requests": requests, "prompt_len": prompt_len,
            "shared_prefix": shared, "gen": gen, "page_size": page_size,
            "prefill_chunk": chunk, "paged_attn_path": _paged_attn_path(),
            "cold_ttft_s": round(cold_ttft, 4),
            "warm_ttft_s": round(warm_ttft, 4),
            "ttft_speedup": round(cold_ttft / max(warm_ttft, 1e-9), 3),
            "prefix_hit_tokens": hits,
            "prefill_skipped_pct": round(
                100.0 * hits / (requests * prompt_len), 1)}


def bench_preemption(*, arch: str, prompt_len: int, gen: int,
                     page_size: int, requests: int, mesh=None) -> dict:
    """Burst workload (PR 7): a queue arrives at once against a page pool
    that holds ONE resident request (plus one spare page), so admission
    is contended.  The defer leg waits for evictions; the preempt leg
    swaps the youngest active slot's pages to host and admits the
    newcomer immediately.  ``sched.ttft`` percentiles show what each
    policy does to admission latency; streams are asserted identical."""
    from repro.configs import get_config, smoke_variant
    from repro.models import transformer as tfm
    from repro.obs import percentiles
    from repro.serve import InferenceEngine, Request, Scheduler

    cfg = smoke_variant(get_config(arch))
    max_len = prompt_len + gen
    pages_per_req = -(-max_len // page_size)
    num_pages = pages_per_req + 1

    def queue():
        return [Request(rid=i, max_new=gen,
                        prompt=np.random.default_rng(7 + i).integers(
                            0, cfg.vocab_size, prompt_len).astype(np.int32))
                for i in range(requests)]

    def leg(preempt):
        engine = InferenceEngine(cfg, slots=2, max_len=max_len, paged=True,
                                 page_size=page_size, num_pages=num_pages,
                                 mesh=mesh)
        state = engine.init_state(tfm.init(cfg, jax.random.key(0)))
        sched = Scheduler(engine, state, preempt=preempt)
        sched.run(queue())              # compile warmup
        sched = Scheduler(engine, sched.state, preempt=preempt)
        streams = sched.run(queue())
        pct = percentiles(sched.ttft.values())
        return {"p50": pct["p50"], "p99": pct["p99"],
                "streams": streams, "stats": dict(sched.stats)}

    base = leg(False)
    pre = leg(True)
    assert pre["streams"] == base["streams"], "preemption changed streams"
    return {"path": "serve_preemption_burst", "arch": cfg.name, "slots": 2,
            "requests": requests, "prompt_len": prompt_len, "gen": gen,
            "page_size": page_size, "num_pages": num_pages,
            "p50_ttft_no_preempt_s": round(base["p50"], 4),
            "p99_ttft_no_preempt_s": round(base["p99"], 4),
            "p50_ttft_preempt_s": round(pre["p50"], 4),
            "p99_ttft_preempt_s": round(pre["p99"], 4),
            "p99_ttft_speedup": round(
                base["p99"] / max(pre["p99"], 1e-9), 3),
            "preemptions": pre["stats"]["preemptions"],
            "restores": pre["stats"]["restores"],
            "deferred_no_preempt": base["stats"]["deferred_admissions"],
            "deferred_preempt": pre["stats"]["deferred_admissions"],
            "max_defer_cycles_no_preempt":
                base["stats"]["max_defer_cycles"]}


def bench_mixed_sampling(*, arch: str, slots: int, requests: int,
                         prompt_len: int, gen: int, spec_k: int,
                         page_size: int, mesh=None) -> dict:
    """Mixed greedy/sampled workload (PR 8): the same queue served three
    ways — all-greedy (the pre-sampling baseline rate), with half the
    requests sampled (per-request temperature/top-k/top-p/penalty/seed in
    one fused batch), and sampled + speculative.  ``sampling_overhead``
    is the mixed/greedy rate ratio (the per-step cost of the vectorized
    sampler); the spec leg shows speculation surviving sampled slots.
    Parity asserted: the greedy rows of the mixed leg bit-match the
    all-greedy leg, and speculation does not change the mixed streams."""
    from repro.configs import get_config, smoke_variant
    from repro.models import transformer as tfm
    from repro.serve import (
        InferenceEngine, NgramDrafter, Request, SamplingParams, Scheduler,
    )

    cfg = smoke_variant(get_config(arch))
    max_len = prompt_len + gen
    sampled = [SamplingParams(temperature=0.8, top_p=0.9, seed=51),
               SamplingParams(temperature=1.0, top_k=40, rep_penalty=1.2,
                              seed=52)]

    def queue(mixed):
        rng = np.random.default_rng(0)
        reqs = []
        for i in range(requests):
            sp = SamplingParams()
            if mixed and i % 2:
                p = sampled[(i // 2) % len(sampled)]
                sp = SamplingParams(temperature=p.temperature, top_k=p.top_k,
                                    top_p=p.top_p, rep_penalty=p.rep_penalty,
                                    seed=p.seed + i)
            reqs.append(Request(
                rid=i, max_new=gen, sampling=sp,
                prompt=rng.integers(0, cfg.vocab_size,
                                    prompt_len).astype(np.int32)))
        return reqs

    def leg(mixed, spec_k_):
        engine = InferenceEngine(cfg, slots=slots, max_len=max_len,
                                 paged=True, page_size=page_size, mesh=mesh)
        state = engine.init_state(tfm.init(cfg, jax.random.key(0)))
        drafter = NgramDrafter() if spec_k_ else None
        sched = Scheduler(engine, state, spec_k=spec_k_, drafter=drafter)
        sched.run(queue(mixed))                     # compile warmup
        best, out = 0.0, None
        for _ in range(2):                          # best-of-2 (CPU noise)
            sched = Scheduler(engine, sched.state, spec_k=spec_k_,
                              drafter=drafter)
            t0 = time.perf_counter()
            out = sched.run(queue(mixed))
            best = max(best, requests * gen / (time.perf_counter() - t0))
        return best, out

    greedy_rate, greedy_out = leg(False, 0)
    mixed_rate, mixed_out = leg(True, 0)
    spec_rate, spec_out = leg(True, spec_k)
    assert spec_out == mixed_out, "speculation changed sampled streams"
    for i in range(0, requests, 2):                 # the greedy rows
        assert mixed_out[i] == greedy_out[i], \
            "a sampled neighbour perturbed a greedy stream"
    return {"path": "serve_mixed_sampling", "arch": cfg.name,
            "slots": slots, "requests": requests, "prompt_len": prompt_len,
            "gen": gen, "spec_k": spec_k, "page_size": page_size,
            "paged_attn_path": _paged_attn_path(),
            "greedy_tok_per_s": round(greedy_rate, 1),
            "mixed_tok_per_s": round(mixed_rate, 1),
            "mixed_spec_tok_per_s": round(spec_rate, 1),
            # mixed/greedy rate quotient: the sampler pipeline's cost on
            # a half-sampled batch (1.0 = free; gated as a ratio key)
            "sampling_overhead_ratio": round(
                mixed_rate / max(greedy_rate, 1e-9), 3)}


def bench_host_tier(*, arch: str, prefix_len: int, tail_len: int, gen: int,
                    page_size: int, families: int, rounds: int,
                    host_mbs, mesh=None) -> dict:
    """Cache-size-vs-hit-rate sweep (PR 9): a forced-spill queue —
    ``families`` alternating shared-prefix families served one slot at a
    time on a pool that only fits ONE request, so every admission
    reclaims the previous family's cached pages — swept over host-tier
    byte budgets (0 = the device-only PR 7 behaviour).  With no host
    tier the radix cache contributes nothing here (every page is gone by
    the time its family returns); with one, the evicted pages spill and
    the family's next request swaps them back in.  Streams are asserted
    bit-identical across every size — the sweep buys latency, never
    different tokens.  ``host_ttft_speedup`` (warm TTFT, no-host /
    largest-budget) is the ratio the regression gate watches."""
    from repro.configs import get_config, smoke_variant
    from repro.models import transformer as tfm
    from repro.serve import InferenceEngine, Request, Scheduler

    cfg = smoke_variant(get_config(arch))
    prompt_len = prefix_len + tail_len
    max_len = prompt_len + gen
    num_pages = -(-max_len // page_size)        # exactly one resident req
    prefixes = [np.random.default_rng(i).integers(
        0, cfg.vocab_size, prefix_len).astype(np.int32)
        for i in range(families)]

    def mk(rid):
        tail = np.random.default_rng(500 + rid).integers(
            0, cfg.vocab_size, tail_len).astype(np.int32)
        return Request(rid=rid, max_new=gen, prompt=np.concatenate(
            [prefixes[rid % families], tail]))

    def leg(host_mb):
        engine = InferenceEngine(cfg, slots=1, max_len=max_len, paged=True,
                                 page_size=page_size, num_pages=num_pages,
                                 mesh=mesh)
        state = engine.init_state(tfm.init(cfg, jax.random.key(0)))
        sched = Scheduler(engine, state, prefix_cache=True,
                          host_cache_bytes=int(host_mb * 2 ** 20))
        streams, ttfts = {}, []
        for rid in range(families * rounds):
            streams[rid] = sched.run([mk(rid)])[rid]
            ttfts.append(sched.ttft[rid])
        # warm TTFT over the LAST round only: round 1 is cold by
        # construction and round 2 pays the resume path's compiles
        warm = float(np.mean(ttfts[(rounds - 1) * families:]))
        st = sched.lifetime_stats
        total_prompt = families * rounds * prompt_len
        return streams, warm, {
            "skipped_pct": round(
                100.0 * st["prefix_hit_tokens"] / total_prompt, 1),
            "host_hits": int(st["host_hits"]),
            "host_restored_pages": int(st["host_restored_pages"]),
            "host_spilled_pages": int(st["host_spilled_pages"])}

    legs = [(mb,) + leg(mb) for mb in host_mbs]
    base_streams = legs[0][1]
    for mb, streams, _, _ in legs[1:]:
        assert streams == base_streams, \
            f"host tier at {mb} MiB changed the streams"
    assert legs[0][3]["host_hits"] == 0                 # no tier, no hits
    assert legs[-1][3]["host_hits"] > 0, legs[-1][3]    # ample tier hits
    row = {"path": "serve_host_tier_sweep", "arch": cfg.name,
           "families": families, "rounds": rounds,
           "prompt_len": prompt_len, "shared_prefix": prefix_len,
           "gen": gen, "page_size": page_size, "num_pages": num_pages,
           "paged_attn_path": _paged_attn_path(),
           "host_cache_mbs": list(host_mbs)}
    for mb, _, warm, st in legs:
        label = str(mb).replace(".", "p")
        row[f"skipped_pct_host_{label}mb"] = st["skipped_pct"]
        row[f"host_hits_{label}mb"] = st["host_hits"]
        row[f"host_restored_pages_{label}mb"] = st["host_restored_pages"]
        row[f"host_spilled_pages_{label}mb"] = st["host_spilled_pages"]
        row[f"warm_ttft_host_{label}mb_s"] = round(warm, 4)
    row["host_ttft_speedup"] = round(legs[0][2] / max(legs[-1][2], 1e-9), 3)
    return row


def bench_forecast(*, watersheds: int, days: int) -> dict:
    from repro.configs import get_config
    from repro.core import domst
    from repro.data.pipeline import make_domst_windows, stacked_test_batch
    from repro.serve import Forecaster

    cfg = get_config("domst")
    windows = make_domst_windows(watersheds, days)
    params = domst.init_stacked(cfg, jax.random.key(0), len(windows))
    fc = Forecaster(cfg)
    held = stacked_test_batch(windows)
    params = fc.place_params(params)
    jax.block_until_ready(fc(params, held)["qhat"])           # compile warmup
    t0 = time.perf_counter()
    res = fc(params, held)
    jax.block_until_ready(res["qhat"])
    wall = time.perf_counter() - t0
    horizon = int(held["discharge"].shape[1])
    return {"path": "serve_domst_forecast", "watersheds": watersheds,
            "horizon_days": horizon, "wall_s": round(wall, 4),
            "forecasts_per_s": round(watersheds * horizon / wall, 1)}


def run(*, smoke: bool = False) -> dict:
    mesh = _bench_mesh()
    if smoke:
        rows = bench_lm(arch="qwen2-1.5b", slots=4, requests=8,
                        prompt_len=12, gen=8, mesh=mesh)
        rows.append(bench_paged(arch="qwen2-1.5b", slots=4, requests=8,
                                prompt_len=12, gen=8, page_size=4,
                                mesh=mesh))
        rows.append(bench_admission(arch="qwen2-1.5b", long_prompt=512,
                                    chunk=32, gen=24, mesh=mesh))
        rows.append(bench_forecast(watersheds=2, days=120))
        spec_rows = [bench_speculative(arch="qwen2-1.5b", slots=4,
                                       requests=8, prompt_len=16, gen=24,
                                       spec_k=3, page_size=8, mesh=mesh)]
        prefix_rows = [
            bench_prefix_cache(arch="qwen2-1.5b", prompt_len=64, shared=48,
                               gen=8, page_size=8, requests=4, chunk=16,
                               mesh=mesh),
            bench_preemption(arch="qwen2-1.5b", prompt_len=16, gen=16,
                             page_size=8, requests=4, mesh=mesh)]
        sampling_rows = [bench_mixed_sampling(
            arch="qwen2-1.5b", slots=4, requests=8, prompt_len=16, gen=16,
            spec_k=3, page_size=8, mesh=mesh)]
        host_rows = [bench_host_tier(
            arch="qwen2-1.5b", prefix_len=16, tail_len=8, gen=8,
            page_size=8, families=2, rounds=3,
            host_mbs=(0.0, 0.01, 8.0), mesh=mesh)]
    else:
        rows = bench_lm(arch="qwen2-1.5b", slots=8, requests=32,
                        prompt_len=32, gen=24, mesh=mesh)
        rows.append(bench_paged(arch="qwen2-1.5b", slots=8, requests=32,
                                prompt_len=32, gen=24, page_size=8,
                                mesh=mesh))
        rows.append(bench_admission(arch="qwen2-1.5b", long_prompt=1024,
                                    chunk=64, gen=48, mesh=mesh))
        rows.append(bench_forecast(watersheds=8, days=400))
        spec_rows = [bench_speculative(arch="qwen2-1.5b", slots=8,
                                       requests=16, prompt_len=32, gen=48,
                                       spec_k=4, page_size=8, mesh=mesh)]
        prefix_rows = [
            bench_prefix_cache(arch="qwen2-1.5b", prompt_len=128, shared=96,
                               gen=16, page_size=8, requests=6, chunk=32,
                               mesh=mesh),
            bench_preemption(arch="qwen2-1.5b", prompt_len=32, gen=32,
                             page_size=8, requests=4, mesh=mesh)]
        sampling_rows = [bench_mixed_sampling(
            arch="qwen2-1.5b", slots=8, requests=16, prompt_len=32, gen=32,
            spec_k=4, page_size=8, mesh=mesh)]
        host_rows = [bench_host_tier(
            arch="qwen2-1.5b", prefix_len=32, tail_len=16, gen=16,
            page_size=8, families=2, rounds=4,
            host_mbs=(0.0, 0.02, 64.0), mesh=mesh)]
    return {"bench": "serve_prefill_decode_batching", "smoke": smoke,
            "backend": jax.default_backend(),
            # device_count = host devices actually visible (CI forces 8 via
            # XLA_FLAGS) and mesh_shape = the mesh EVERY bench engine above
            # actually ran on (_bench_mesh threads it through; a past bug
            # recorded a degenerate 1x1 default here).  Both identify the
            # environment: the regression gate skips absolute-throughput
            # comparison when either differs.
            "device_count": len(jax.devices()),
            "mesh_shape": {name: int(size) for name, size in
                           zip(mesh.axis_names, mesh.devices.shape)},
            "rows": rows,
            # written to the --spec-out file (BENCH_PR5.json) as their own
            # baseline doc; kept separate so the two gates evolve freely
            "spec_rows": spec_rows,
            # written to the --pr7-out file (BENCH_PR7.json): prefix-cache
            # TTFT + preemption burst rows, again their own baseline doc
            "prefix_rows": prefix_rows,
            # written to the --pr8-out file (BENCH_PR8.json): the mixed
            # greedy/sampled workload row, its own baseline doc
            "sampling_rows": sampling_rows,
            # written to the --pr9-out file (BENCH_PR9.json): the host-tier
            # cache-size-vs-hit-rate sweep row, its own baseline doc
            "host_rows": host_rows}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small sizes for CI")
    ap.add_argument("--out", default="BENCH_PR3.json")
    ap.add_argument("--spec-out", default="BENCH_PR5.json",
                    help="speculative-decoding rows (their own baseline)")
    ap.add_argument("--pr7-out", default="BENCH_PR7.json",
                    help="prefix-cache / preemption rows (their own "
                         "baseline)")
    ap.add_argument("--pr8-out", default="BENCH_PR8.json",
                    help="mixed greedy/sampled workload row (its own "
                         "baseline)")
    ap.add_argument("--pr9-out", default="BENCH_PR9.json",
                    help="host-tier cache-size sweep row (its own "
                         "baseline)")
    args = ap.parse_args()
    res = run(smoke=args.smoke)
    spec_rows = res.pop("spec_rows")
    prefix_rows = res.pop("prefix_rows")
    sampling_rows = res.pop("sampling_rows")
    host_rows = res.pop("host_rows")
    for r in res["rows"] + spec_rows + prefix_rows + sampling_rows \
            + host_rows:
        print(json.dumps(r), flush=True)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
        f.write("\n")
    spec = dict(res, bench="serve_speculative", rows=spec_rows)
    with open(args.spec_out, "w") as f:
        json.dump(spec, f, indent=2)
        f.write("\n")
    pr7 = dict(res, bench="serve_prefix_preempt", rows=prefix_rows)
    with open(args.pr7_out, "w") as f:
        json.dump(pr7, f, indent=2)
        f.write("\n")
    pr8 = dict(res, bench="serve_sampling", rows=sampling_rows)
    with open(args.pr8_out, "w") as f:
        json.dump(pr8, f, indent=2)
        f.write("\n")
    pr9 = dict(res, bench="serve_host_tier", rows=host_rows)
    with open(args.pr9_out, "w") as f:
        json.dump(pr9, f, indent=2)
        f.write("\n")
    print("wrote", args.out, ",", args.spec_out, ",", args.pr7_out, ",",
          args.pr8_out, "and", args.pr9_out)


if __name__ == "__main__":
    main()
