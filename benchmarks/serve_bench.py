"""Serving benchmark (PR 3): prefill vs decode throughput through the
sharded inference engine, and continuous batching vs sequential requests.

For the LM path the SAME engine and request queue are driven twice —
``slots=1`` (one request at a time to completion, the pre-PR-3 shape) and
``slots=N`` (continuous batching: fused all-slot decode, EOS eviction,
in-place slot reuse) — plus the Dom-ST forecast workload, all recorded to
``BENCH_PR3.json``:

    python -m benchmarks.serve_bench [--smoke] [--out BENCH_PR3.json]

``--smoke`` shrinks sizes for CI; the numbers are honest either way (on a
shared-core CPU container the batching win is modest — the bench exists
so the trajectory is tracked, and so real hardware has a ready
measurement).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np


def _make_requests(cfg, n, prompt_len, gen, seed=0):
    from repro.serve import Request
    rng = np.random.default_rng(seed)
    return [Request(rid=i, max_new=gen,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        prompt_len).astype(np.int32))
            for i in range(n)]


def _run_queue(cfg, params_key, *, slots, requests, prompt_len, gen):
    """(scheduler stats, wall seconds) for one served queue."""
    from repro.models import transformer as tfm
    from repro.serve import InferenceEngine, Scheduler

    engine = InferenceEngine(cfg, slots=slots, max_len=prompt_len + gen)
    state = engine.init_state(tfm.init(cfg, jax.random.key(params_key)))
    sched = Scheduler(engine, state)
    sched.run(_make_requests(cfg, slots, prompt_len, gen))    # compile warmup
    sched = Scheduler(engine, sched.state)
    t0 = time.perf_counter()
    out = sched.run(_make_requests(cfg, requests, prompt_len, gen))
    wall = time.perf_counter() - t0
    assert sum(len(g) for g in out.values()) == requests * gen
    return sched.stats, wall


def bench_lm(*, arch: str, slots: int, requests: int, prompt_len: int,
             gen: int) -> list:
    from repro.configs import get_config, smoke_variant

    cfg = smoke_variant(get_config(arch))
    st, batched_s = _run_queue(cfg, 0, slots=slots, requests=requests,
                               prompt_len=prompt_len, gen=gen)
    _, seq_s = _run_queue(cfg, 0, slots=1, requests=requests,
                          prompt_len=prompt_len, gen=gen)
    tokens = requests * gen
    return [
        {"path": "serve_prefill_vs_decode", "arch": cfg.name, "slots": slots,
         "requests": requests, "prompt_len": prompt_len, "gen": gen,
         "prefill_tok_per_s": round(
             st["prefill_tokens"] / max(st["prefill_s"], 1e-9), 1),
         "decode_tok_per_s": round(
             st["decode_tokens"] / max(st["decode_s"], 1e-9), 1)},
        {"path": "serve_batched_vs_sequential", "arch": cfg.name,
         "slots": slots, "requests": requests, "gen": gen,
         "batched_tok_per_s": round(tokens / batched_s, 1),
         "sequential_tok_per_s": round(tokens / seq_s, 1),
         "speedup": round(seq_s / batched_s, 3)},
    ]


def bench_forecast(*, watersheds: int, days: int) -> dict:
    from repro.configs import get_config
    from repro.core import domst
    from repro.data.pipeline import make_domst_windows, stacked_test_batch
    from repro.serve import Forecaster

    cfg = get_config("domst")
    windows = make_domst_windows(watersheds, days)
    params = domst.init_stacked(cfg, jax.random.key(0), len(windows))
    fc = Forecaster(cfg)
    held = stacked_test_batch(windows)
    params = fc.place_params(params)
    jax.block_until_ready(fc(params, held)["qhat"])           # compile warmup
    t0 = time.perf_counter()
    res = fc(params, held)
    jax.block_until_ready(res["qhat"])
    wall = time.perf_counter() - t0
    horizon = int(held["discharge"].shape[1])
    return {"path": "serve_domst_forecast", "watersheds": watersheds,
            "horizon_days": horizon, "wall_s": round(wall, 4),
            "forecasts_per_s": round(watersheds * horizon / wall, 1)}


def run(*, smoke: bool = False) -> dict:
    if smoke:
        rows = bench_lm(arch="qwen2-1.5b", slots=4, requests=8,
                        prompt_len=12, gen=8)
        rows.append(bench_forecast(watersheds=2, days=120))
    else:
        rows = bench_lm(arch="qwen2-1.5b", slots=8, requests=32,
                        prompt_len=32, gen=24)
        rows.append(bench_forecast(watersheds=8, days=400))
    return {"bench": "serve_prefill_decode_batching", "smoke": smoke,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(), "rows": rows}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small sizes for CI")
    ap.add_argument("--out", default="BENCH_PR3.json")
    args = ap.parse_args()
    res = run(smoke=args.smoke)
    for r in res["rows"]:
        print(json.dumps(r), flush=True)
    with open(args.out, "w") as f:
        json.dump(res, f, indent=2)
        f.write("\n")
    print("wrote", args.out)


if __name__ == "__main__":
    main()
