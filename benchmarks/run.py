"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * fig3_*    — paper Fig. 3 (NSE: Singlehead / Singlehead(+P) / Dom-ST)
  * table1_*  — paper Table 1 (sequential vs IP-D wall time + speedup)
  * kernel_*  — Pallas kernel micro-benches vs jnp oracle
  * loader_*  — input-pipeline steps/sec, sync loop vs ShardedLoader prefetch
  * serve_*   — inference engine: prefill vs decode tokens/sec, continuous
                batching vs sequential requests, paged vs contiguous KV
                cache, chunked-prefill admission latency, Dom-ST forecast
                rate
  * roofline_* — summary of the dry-run roofline terms (if results exist)

Full-scale (23-watershed) variants: ``python -m benchmarks.fig3_nse --full``
and ``python -m benchmarks.table1_pipeline --full`` (used for
EXPERIMENTS.md §Paper).
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)


def bench_fig3() -> None:
    from benchmarks import fig3_nse
    res = fig3_nse.run(num_watersheds=4, days=220, iters=100)
    per_ws_us = res["wall_s"] / (res["num_watersheds"] * 3) * 1e6
    m = res["mean_nse"]
    emit("fig3_singlehead", per_ws_us, f"mean_nse={m['Singlehead']:.3f}")
    emit("fig3_singlehead_p", per_ws_us,
         f"mean_nse={m['Singlehead(+P)']:.3f};"
         f"pct_improved={res['pct_improved_by_P']:.0f}%")
    emit("fig3_domst", per_ws_us,
         f"mean_nse={m['Distributed-Multihead(+P)']:.3f};"
         f"beats_singlehead={res['pct_domst_beats_singlehead']:.0f}%")


def bench_table1() -> None:
    from benchmarks import table1_pipeline
    res = table1_pipeline.run(num_watersheds=6, days=220, epochs=1)
    for label, key in (("table1_singlehead_p", "Singlehead(+P)"),
                       ("table1_multihead_p", "Distributed-Multihead(+P)")):
        r = res[key]
        emit(label, r["time_IPD_s"] * 1e6,
             f"S={r['time_S_s']}s;IPD={r['time_IPD_s']}s;"
             f"speedup={r['speedup']}x")


def bench_kernels() -> None:
    from benchmarks import kernels_bench
    for name, us, derived in kernels_bench.rows():
        emit(f"kernel_{name}", us, derived)


def bench_loader() -> None:
    from benchmarks import loader_bench
    res = loader_bench.run(smoke=True)
    for r in res["rows"]:
        emit(f"loader_{r['path']}", 1e6 / max(r["prefetch_steps_per_s"], 1e-9),
             f"sync={r['sync_steps_per_s']}steps/s;"
             f"prefetch={r['prefetch_steps_per_s']}steps/s;"
             f"speedup={r['speedup']}x")


def bench_serve() -> None:
    from benchmarks import serve_bench
    res = serve_bench.run(smoke=True)
    for r in res["rows"]:
        if r["path"] == "serve_prefill_vs_decode":
            emit("serve_prefill_vs_decode",
                 1e6 / max(r["decode_tok_per_s"], 1e-9),
                 f"prefill={r['prefill_tok_per_s']}tok/s;"
                 f"decode={r['decode_tok_per_s']}tok/s")
        elif r["path"] == "serve_batched_vs_sequential":
            emit("serve_batched_vs_sequential",
                 1e6 / max(r["batched_tok_per_s"], 1e-9),
                 f"seq={r['sequential_tok_per_s']}tok/s;"
                 f"batched={r['batched_tok_per_s']}tok/s;"
                 f"speedup={r['speedup']}x")
        elif r["path"] == "serve_paged_vs_contiguous":
            emit("serve_paged_vs_contiguous",
                 1e6 / max(r["paged_tok_per_s"], 1e-9),
                 f"contig={r['contiguous_tok_per_s']}tok/s;"
                 f"paged={r['paged_tok_per_s']}tok/s;"
                 f"cache_mem_ratio={r['cache_mem_ratio']}x")
        elif r["path"] == "serve_admission_latency":
            emit("serve_admission_latency",
                 r["chunked_prefill_stall_s"] * 1e6,
                 f"whole_stall={r['whole_prefill_stall_s']}s;"
                 f"chunked_stall={r['chunked_prefill_stall_s']}s;"
                 f"ratio={r['stall_ratio']}x")
        elif r["path"] == "serve_domst_forecast":
            emit("serve_domst_forecast",
                 1e6 / max(r["forecasts_per_s"], 1e-9),
                 f"forecasts_per_s={r['forecasts_per_s']};"
                 f"horizon={r['horizon_days']}d")
    for r in res.get("spec_rows", []):
        emit("serve_speculative",
             1e6 / max(r["model_tok_per_s"], 1e-9),
             f"baseline={r['baseline_tok_per_s']}tok/s;"
             f"ngram={r['ngram_tok_per_s']}tok/s"
             f"@{r['ngram_accepted_per_step']}tok/step;"
             f"model={r['model_tok_per_s']}tok/s"
             f"@{r['model_accepted_per_step']}tok/step")


def bench_roofline() -> None:
    from benchmarks import roofline
    rows = roofline.load_all()
    if not rows:
        emit("roofline_dryrun", 0.0, "no results/dryrun yet")
        return
    pod = [r for r in rows if r["mesh"] == "pod"]
    for r in pod:
        t = max(r["compute_ms"], r["memory_ms"], r["collective_ms"])
        emit(f"roofline_{r['arch']}_{r['shape']}", t * 1e3,
             f"dominant={r['dominant']};useful={r['useful_ratio']};"
             f"fits={'y' if r['hbm_fit'] else 'n'}")


def main() -> None:
    print("name,us_per_call,derived")
    bench_kernels()
    bench_fig3()
    bench_table1()
    bench_loader()
    bench_serve()
    bench_roofline()


if __name__ == "__main__":
    main()
