"""Paper Fig. 3: NSE comparison of Singlehead vs Singlehead(+P) vs
Distributed-Multihead(+P) (= Dom-ST) across watersheds.

Reproduces the paper's claims on the synthetic 23-watershed dataset:
  * (+P) improves most watersheds (~91% in the paper),
  * Dom-ST beats both baselines on most watersheds,
  * highest individual NSE increase (paper: up to 93%).
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_config
from repro.core import domst
from repro.data import generate_all_watersheds, make_training_windows
from repro.data.pipeline import train_test_split
from repro.optim import make_optimizer

VARIANTS = ("domst-singlehead", "domst-singlehead-p", "domst")
LABELS = {"domst-singlehead": "Singlehead",
          "domst-singlehead-p": "Singlehead(+P)",
          "domst": "Distributed-Multihead(+P)"}


def train_one(cfg_name: str, w, *, iters: int, seed: int) -> float:
    cfg = get_config(cfg_name)
    tr, te = train_test_split(w)
    tc = TrainConfig(learning_rate=3e-3, total_steps=iters, warmup_steps=10)
    params = domst.init(cfg, jax.random.key(seed + w.watershed_id))
    step = domst.make_train_step(cfg, tc)
    opt = make_optimizer(tc)[0](params)
    rng = np.random.default_rng(seed)
    n = len(tr["discharge"])
    for _ in range(iters):
        sl = rng.integers(0, n, 64)
        b = {k: jnp.asarray(v[sl]) for k, v in tr.items()}
        params, opt, _ = step(params, opt, b)
    te_j = {k: jnp.asarray(v) for k, v in te.items()}
    return float(domst.evaluate(params, cfg, te_j)["nse"])


def run(num_watersheds: int = 8, days: int = 300, iters: int = 150,
        seed: int = 0) -> Dict:
    data = generate_all_watersheds(num_watersheds, num_days=days)
    windows = [make_training_windows(w) for w in data.values()]
    nse: Dict[str, List[float]] = {v: [] for v in VARIANTS}
    t0 = time.perf_counter()
    for w in windows:
        for v in VARIANTS:
            nse[v].append(train_one(v, w, iters=iters, seed=seed))
    wall = time.perf_counter() - t0

    s, sp, dm = (np.asarray(nse[v]) for v in VARIANTS)
    res = {
        "num_watersheds": num_watersheds,
        "mean_nse": {LABELS[v]: float(np.mean(nse[v])) for v in VARIANTS},
        "pct_improved_by_P": float(np.mean(sp > s) * 100),
        "pct_domst_beats_singlehead": float(np.mean(dm > s) * 100),
        "pct_domst_beats_singlehead_p": float(np.mean(dm > sp) * 100),
        "max_individual_nse_gain_pct": float(
            np.max((dm - s) / np.maximum(np.abs(s), 1e-6)) * 100),
        "mean_nse_gain_pct": float(
            (np.mean(dm) - np.mean(s)) / max(abs(np.mean(s)), 1e-6) * 100),
        "per_watershed": {str(i): {LABELS[v]: round(nse[v][i], 4)
                                   for v in VARIANTS}
                          for i in range(num_watersheds)},
        "wall_s": round(wall, 1),
    }
    return res


def main(full: bool = False):
    kw = dict(num_watersheds=23, days=400, iters=200) if full else \
        dict(num_watersheds=6, days=250, iters=120)
    res = run(**kw)
    os.makedirs("results", exist_ok=True)
    path = "results/fig3_nse%s.json" % ("_full" if full else "")
    with open(path, "w") as f:
        json.dump(res, f, indent=2)
    print(json.dumps({k: v for k, v in res.items()
                      if k != "per_watershed"}, indent=2))
    return res


if __name__ == "__main__":
    import sys
    main(full="--full" in sys.argv)
