"""Paper Table 1: input-pipeline distribution (IP-D) speedup.

Compares total multi-watershed training wall time:
  * S    — sequential: one watershed at a time, one model at a time
           (the paper's single-device baseline), vs
  * IP-D — the distributed input pipeline: all watershed replicas trained
           in one vectorized step (watershed axis -> mesh data axis on TPU;
           vmap over host cores here).

Paper numbers: Singlehead(+P) 8.5x, Distributed-Multihead(+P) 12.6x.
On CPU the attainable speedup is bounded by core count and memory
bandwidth, not by the 23 GPUs the paper used — the *structure* (IP-D >> S,
multihead benefiting more) is the claim under test.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_config
from repro.core import domst
from repro.data import generate_all_watersheds, make_training_windows
from repro.data.pipeline import InputPipeline
from repro.optim import make_optimizer


def time_sequential(cfg_name: str, windows, ip: InputPipeline,
                    epochs: int) -> float:
    cfg = get_config(cfg_name)
    tc = TrainConfig(learning_rate=3e-3, total_steps=1000, warmup_steps=10)
    step = domst.make_train_step(cfg, tc)
    opt_init, _ = make_optimizer(tc)
    # warmup compile once (excluded, as the paper reports steady-state hours)
    w0 = windows[0]
    params = domst.init(cfg, jax.random.key(0))
    opt = opt_init(params)
    b = next(iter(ip.batches(w0, 0)))
    step(params, opt, {k: jnp.asarray(v) for k, v in b.items()})[2][
        "loss"].block_until_ready()
    t0 = time.perf_counter()
    for w in windows:
        params = domst.init(cfg, jax.random.key(w.watershed_id))
        opt = opt_init(params)
        for epoch in range(epochs):
            for b in ip.batches(w, epoch):
                params, opt, m = step(
                    params, opt, {k: jnp.asarray(v) for k, v in b.items()})
    m["loss"].block_until_ready()
    return time.perf_counter() - t0


def time_ipd(cfg_name: str, windows, ip: InputPipeline, epochs: int) -> float:
    cfg = get_config(cfg_name)
    tc = TrainConfig(learning_rate=3e-3, total_steps=1000, warmup_steps=10)
    step = domst.make_stacked_train_step(cfg, tc)
    params = domst.init_stacked(cfg, jax.random.key(0), len(windows))
    opt = jax.vmap(make_optimizer(tc)[0])(params)
    b = next(iter(ip.stacked_batches(0)))
    b = {k: jnp.asarray(v) for k, v in b.items()}
    step(params, opt, b)[2]["loss"].block_until_ready()   # compile warmup
    t0 = time.perf_counter()
    for epoch in range(epochs):
        for b in ip.stacked_batches(epoch):
            params, opt, m = step(
                params, opt, {k: jnp.asarray(v) for k, v in b.items()})
    m["loss"].block_until_ready()
    return time.perf_counter() - t0


def run(num_watersheds: int = 8, days: int = 250, epochs: int = 2,
        batch_size: int = 64) -> Dict:
    data = generate_all_watersheds(num_watersheds, num_days=days)
    windows = [make_training_windows(w) for w in data.values()]
    ip = InputPipeline(windows, batch_size=batch_size)
    out: Dict = {"num_watersheds": num_watersheds, "epochs": epochs}
    for name, label in (("domst-singlehead-p", "Singlehead(+P)"),
                        ("domst", "Distributed-Multihead(+P)")):
        t_seq = time_sequential(name, windows, ip, epochs)
        t_ipd = time_ipd(name, windows, ip, epochs)
        out[label] = {"time_S_s": round(t_seq, 2),
                      "time_IPD_s": round(t_ipd, 2),
                      "speedup": round(t_seq / t_ipd, 2)}
    return out


def main(full: bool = False):
    kw = dict(num_watersheds=23, days=400, epochs=3) if full else \
        dict(num_watersheds=8, days=250, epochs=2)
    res = run(**kw)
    os.makedirs("results", exist_ok=True)
    with open("results/table1_pipeline%s.json" % ("_full" if full else ""),
              "w") as f:
        json.dump(res, f, indent=2)
    print(json.dumps(res, indent=2))
    return res


if __name__ == "__main__":
    import sys
    main(full="--full" in sys.argv)
