"""Bench-regression gate: compare fresh smoke-bench results against the
committed ``BENCH_*.json`` baselines and fail on a real throughput drop.

    python -m benchmarks.check_regression BENCH_PR2.json=fresh/BENCH_PR2.json \
        BENCH_PR3.json=fresh/BENCH_PR3.json [--tolerance 0.3]

Each positional argument is ``<committed baseline>=<fresh result>``.  The
diff is deliberately TOLERANT — keys-only, never schema-strict — so the
gate survives bench evolution:

  * rows are matched by their ``path`` key (+ ``arch`` when present);
    rows that exist on only one side are reported but never fail;
  * only throughput-like keys are compared: ``*_per_s`` plus dimensionless
    ratios (``speedup``, ``*_ratio``).  Wall-clock-absolute fields
    (``*_s``, ``*_mib``, counts, shapes) are skipped — they measure the
    machine and the config, not the code;
  * absolute ``*_per_s`` keys are only compared when the two files ran in
    the same environment (``smoke`` flag, ``device_count`` AND the
    recorded ``mesh_shape`` match — a 1x1-mesh run is not comparable to
    an 8-way-data run on the same host) and the two rows ran the same
    workload (all shared config scalars equal); ratio keys are always
    comparable — EXCEPT ``fused_speedup`` when the baseline ran its
    kernels in interpret mode (``"interpret": true``): an interpreter
    ratio is not a perf signal and must not constrain real-hardware
    runs (``allclose_err`` fields are neither ratios nor throughputs,
    so correctness checking is untouched);
  * a throughput key regresses when ``fresh < baseline * (1 - tolerance)``
    — the default 0.3 fails on a >30% drop.  Ratio keys are quotients of
    two wall-clock timings (noisier by construction), so they use the
    wider ``--ratio-tolerance`` (default 0.6): a speedup collapsing to
    less than 40% of its baseline still fails, scheduler jitter does not.

Exit status 1 iff any compared key regresses.

``--write-baseline`` flips the tool from gate to refresh: each pair's
committed baseline file is REWRITTEN from its fresh result, preserving
the baseline's top-level key order (a refresh produces a reviewable
diff, not a reshuffle) and refusing when the fresh run's environment
(``smoke`` / ``device_count`` / ``mesh_shape``) differs from the
committed one — a laptop run must never silently become the CI
baseline.  Workflow in ``benchmarks/README.md``.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Optional

RATIO_KEYS = ("speedup",)
# _speedup: named speedups (ngram_speedup, ...); _per_step: accepted tokens
# per fused decode step (speculative decoding) — dimensionless and workload-
# determined like the other ratios, so they gate at the wide tolerance
RATIO_SUFFIXES = ("_ratio", "_speedup", "_per_step")
THROUGHPUT_SUFFIXES = ("_per_s",)


def _is_ratio(key: str) -> bool:
    return key in RATIO_KEYS or key.endswith(RATIO_SUFFIXES)


def _is_throughput(key: str) -> bool:
    return key.endswith(THROUGHPUT_SUFFIXES)


def _row_key(row: dict) -> str:
    return f"{row.get('path', '?')}[{row.get('arch', '-')}]"


def _same_workload(a: dict, b: dict) -> bool:
    """True when every config scalar the two rows share is equal (the
    throughput numbers then measure the same work)."""
    for k in set(a) & set(b):
        if _is_ratio(k) or _is_throughput(k) or k.endswith("_s") \
                or k.endswith("_mib"):
            continue
        if a[k] != b[k]:
            return False
    return True


def compare_files(base_path: str, fresh_path: str, tolerance: float,
                  ratio_tolerance: float, out=sys.stdout) -> list:
    with open(base_path) as f:
        base = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)
    env_match = base.get("smoke") == fresh.get("smoke") \
        and base.get("device_count") == fresh.get("device_count") \
        and base.get("mesh_shape") == fresh.get("mesh_shape")
    base_rows = {_row_key(r): r for r in base.get("rows", [])}
    regressions = []
    for row in fresh.get("rows", []):
        key = _row_key(row)
        ref = base_rows.get(key)
        if ref is None:
            print(f"  {key}: new row (no baseline) — skipped", file=out)
            continue
        comparable_abs = env_match and _same_workload(ref, row)
        for k in sorted(set(ref) & set(row)):
            if _is_ratio(k):
                if k.endswith("fused_speedup") and base.get("interpret"):
                    # interpret-mode kernel ratios (CPU CI) measure the
                    # Pallas interpreter, not the code — 0.08x baselines
                    # must not constrain real-hardware runs
                    print(f"  {key}.{k}: baseline ran kernels in interpret "
                          f"mode — ratio skipped", file=out)
                    continue
                tol = ratio_tolerance                   # always comparable
            elif _is_throughput(k):
                if not comparable_abs:
                    print(f"  {key}.{k}: environment/workload differs — "
                          f"absolute throughput skipped", file=out)
                    continue
                tol = tolerance
            else:
                continue                                # config / wall-clock
            b, f_ = float(ref[k]), float(row[k])
            floor = b * (1.0 - tol)
            verdict = "REGRESSION" if f_ < floor else "ok"
            print(f"  {key}.{k}: baseline={b} fresh={f_} "
                  f"floor={floor:.3f} -> {verdict}", file=out)
            if f_ < floor:
                regressions.append((key, k, b, f_))
    for key in base_rows:
        if key not in {_row_key(r) for r in fresh.get("rows", [])}:
            print(f"  {key}: baseline row missing from fresh results — "
                  f"skipped", file=out)
    return regressions


def write_baseline(base_path: str, fresh_path: str, out=sys.stdout) -> None:
    """Rewrite the committed ``base_path`` from ``fresh_path``.

    The fresh document's values win wholesale, but the COMMITTED file's
    top-level key order is preserved (fresh-only keys append at the end)
    so a refresh reads as a value diff in review.  Refuses when the two
    documents disagree on the environment triple — a baseline regenerated
    on the wrong mesh would silently loosen (or jam) the gate."""
    with open(base_path) as f:
        base = json.load(f)
    with open(fresh_path) as f:
        fresh = json.load(f)
    for k in ("smoke", "device_count", "mesh_shape"):
        if k in base and k in fresh and base[k] != fresh[k]:
            raise SystemExit(
                f"refusing to rewrite {base_path}: fresh run's '{k}' is "
                f"{fresh[k]!r} but the committed baseline recorded "
                f"{base[k]!r} — regenerate from a matching environment")
    merged = {k: fresh[k] for k in base if k in fresh}
    merged.update({k: v for k, v in fresh.items() if k not in merged})
    with open(base_path, "w") as f:
        json.dump(merged, f, indent=2)
        f.write("\n")
    print(f"  {base_path}: baseline rewritten from {fresh_path} "
          f"({len(merged.get('rows', []))} rows)", file=out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("pairs", nargs="+",
                    help="<committed baseline>=<fresh result> json pairs")
    ap.add_argument("--tolerance", type=float, default=0.3,
                    help="allowed fractional drop before failing "
                         "(0.3 = fail on >30%% regression)")
    ap.add_argument("--ratio-tolerance", type=float, default=0.6,
                    help="wider floor for dimensionless ratio keys, which "
                         "are quotients of two noisy timings")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite each committed baseline from its fresh "
                         "result (key order preserved; refuses on "
                         "smoke/device_count/mesh_shape mismatch) instead "
                         "of gating")
    args = ap.parse_args(argv)
    if args.write_baseline:
        for pair in args.pairs:
            base_path, _, fresh_path = pair.partition("=")
            if not fresh_path:
                ap.error(f"pair '{pair}' is not of the form baseline=fresh")
            write_baseline(base_path, fresh_path)
        return 0
    all_regressions = []
    for pair in args.pairs:
        base_path, _, fresh_path = pair.partition("=")
        if not fresh_path:
            ap.error(f"pair '{pair}' is not of the form baseline=fresh")
        print(f"{base_path} vs {fresh_path}:")
        all_regressions += compare_files(base_path, fresh_path,
                                         args.tolerance,
                                         args.ratio_tolerance)
    if all_regressions:
        print(f"\nFAIL: {len(all_regressions)} throughput regression(s) "
              f"beyond tolerance ({args.tolerance:.0%} absolute, "
              f"{args.ratio_tolerance:.0%} ratios):")
        for key, k, b, f_ in all_regressions:
            print(f"  {key}.{k}: {b} -> {f_} "
                  f"({(f_ / b - 1) * 100:+.1f}%)")
        return 1
    print("\nOK: no throughput regressions beyond tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
