"""Serve-CLI smoke checks — the single entry point CI and local runs share.

Each subcommand drives ``repro.launch.serve`` end to end (subprocess, real
CLI) and asserts the same reproducibility bar the PR acceptance criteria
pin.  The exact commands CI runs work locally:

  export XLA_FLAGS=--xla_force_host_platform_device_count=8
  PYTHONPATH=src python scripts/ci_smoke.py prefix
  PYTHONPATH=src python scripts/ci_smoke.py sampling
  PYTHONPATH=src python scripts/ci_smoke.py host-tier

Subcommands:

* ``prefix``    — the same shared-prefix queue served plain and with
                  ``--prefix-cache --preempt`` must emit bit-identical
                  streams, and the cached run must actually skip prefill
                  work (``prefix_hit_tokens > 0``).
* ``sampling``  — a sampled queue served with speculation on must be
                  reproducible (two invocations at the same
                  ``--sample-seed`` print the same stream digest) and
                  must actually sample (every request non-greedy).
* ``host-tier`` — a FORCED-SPILL queue (two alternating prefix families
                  on a pool sized below either family, so each admission
                  evicts the other family's cached pages) served with and
                  without ``--host-cache-mb`` must emit bit-identical
                  streams; the host-tier run must record
                  ``prefix_hit_tokens > 0`` and ``prefill_skipped_pct >
                  0`` where the no-host-tier run records 0 — the spilled
                  pages were genuinely swapped back in, not re-prefilled.
* ``obs``       — the same queue served with and without
                  ``--trace-out/--metrics-out`` must emit bit-identical
                  streams (observability is a pure observer); the saved
                  trace must be valid Chrome trace-event JSON whose spans
                  cover >= 95% of the serve window, and the span-derived
                  TTFTs must match the legacy per-request TTFT dict (the
                  ``serve.ttft_s`` series in the metrics JSONL) within
                  1 ms.  Artifacts land in ``--out-dir`` so CI can upload
                  them.

No inline Python lives in ``ci.yml``; this file IS the smoke suite.  It is
also the format-gated exemplar: ``ruff format --check scripts/`` runs in
the lint job, so keep this file formatter-clean.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

import numpy as np


def run_serve(extra, base=None):
    """Run the serve CLI; return (parsed JSON doc, ``req N: ...`` lines)."""
    cmd = [sys.executable, "-m", "repro.launch.serve"] + (base or []) + extra
    out = subprocess.run(cmd, check=True, capture_output=True, text=True).stdout
    lines = out.strip().splitlines()
    doc = json.loads([ln for ln in lines if ln.startswith("{")][0])
    streams = [ln for ln in lines if ln.startswith("req ")]
    return doc, streams


def smoke_prefix(args) -> None:
    base = ["--arch", args.arch, "--smoke", "--requests", "4"]
    base += ["--batch-size", "2", "--prompt-len", "24", "--gen", "8"]
    base += ["--page-size", "8", "--shared-prefix", "18"]
    plain_doc, plain_streams = run_serve([], base)
    cache_doc, cache_streams = run_serve(["--prefix-cache", "--preempt"], base)
    assert cache_streams == plain_streams, (plain_streams, cache_streams)
    assert cache_doc["prefix_hit_tokens"] > 0, cache_doc
    keys = "prefix_hits prefix_hit_tokens prefix_hit_rate cow_pages".split()
    keys += ["preemptions", "restores"]
    print("prefix-cache parity ok:", {k: cache_doc[k] for k in keys})


def smoke_sampling(args) -> None:
    base = ["--arch", args.arch, "--smoke", "--requests", "4"]
    base += ["--batch-size", "2", "--prompt-len", "12", "--gen", "8", "--ragged"]
    base += ["--temperature", "0.8", "--top-p", "0.9"]
    base += ["--spec-k", "4", "--sample-seed", "7"]

    def digest():
        doc, _ = run_serve([], base)
        assert doc["sampled_requests"] == 4, doc
        return doc["stream_digest"]

    a, b = digest(), digest()
    assert a == b, (a, b)
    print("sampled serve reproducible, digest", a)


def write_spill_queue(path, families=2, per_family=2, prefix_len=16, tail_len=8):
    """An alternating multi-family queue: request i uses family i %
    ``families``.  Served one slot at a time on a pool that only fits one
    request, each admission reclaims the previous family's cached pages —
    with a host tier those pages SPILL and the family's next request
    restores them; without one the cache contributes nothing."""
    rng = np.random.default_rng(0)
    prefixes = [rng.integers(0, 512, prefix_len).tolist() for _ in range(families)]
    entries = []
    for i in range(families * per_family):
        tail = rng.integers(0, 512, tail_len).tolist()
        entries.append({"prompt": prefixes[i % families] + tail})
    with open(path, "w") as f:
        json.dump(entries, f)


def smoke_host_tier(args) -> None:
    fd, qpath = tempfile.mkstemp(suffix=".json", prefix="ci_spill_queue_")
    os.close(fd)
    try:
        write_spill_queue(qpath)
        # prompt 24 @ page 8 + gen 8 -> 4 pages/request == the whole pool:
        # every admission must reclaim the previous request's cached pages
        base = ["--arch", args.arch, "--smoke", "--batch-size", "1"]
        base += ["--gen", "8", "--page-size", "8", "--num-pages", "4"]
        base += ["--queue", qpath, "--prefix-cache"]
        cold_doc, cold_streams = run_serve([], base)
        mb = str(args.host_cache_mb)
        host_doc, host_streams = run_serve(["--host-cache-mb", mb], base)
        assert host_streams == cold_streams, (cold_streams, host_streams)
        assert host_doc["stream_digest"] == cold_doc["stream_digest"]
        # without a host tier the forced-spill queue cannot hit at all
        assert cold_doc["prefix_hit_tokens"] == 0, cold_doc
        assert cold_doc["prefill_skipped_pct"] == 0, cold_doc
        # with one, the spilled prefix pages come back as real hits
        assert host_doc["prefix_hit_tokens"] > 0, host_doc
        assert host_doc["prefill_skipped_pct"] > 0, host_doc
        assert host_doc["host_hits"] > 0, host_doc
        assert host_doc["host_spilled_pages"] > 0, host_doc
        keys = "prefix_hit_tokens prefill_skipped_pct host_hits".split()
        keys += ["host_hit_tokens", "host_restored_pages", "host_spilled_pages"]
        print("host-tier parity ok:", {k: host_doc[k] for k in keys})
    finally:
        os.unlink(qpath)


def smoke_obs(args) -> None:
    os.makedirs(args.out_dir, exist_ok=True)
    trace_path = os.path.join(args.out_dir, "trace.json")
    metrics_path = os.path.join(args.out_dir, "metrics.jsonl")
    base = ["--arch", args.arch, "--smoke", "--requests", "4"]
    base += ["--batch-size", "2", "--prompt-len", "24", "--gen", "8"]
    base += ["--prefill-chunk", "8"]
    plain_doc, plain_streams = run_serve([], base)
    flags = ["--trace-out", trace_path, "--metrics-out", metrics_path]
    obs_doc, obs_streams = run_serve(flags, base)
    # observability must be a pure observer: bit-identical streams
    assert obs_streams == plain_streams, (plain_streams, obs_streams)
    assert obs_doc["stream_digest"] == plain_doc["stream_digest"]

    with open(trace_path) as f:
        trace = json.load(f)
    events = trace["traceEvents"]
    spans = [e for e in events if e.get("ph") == "X"]
    assert spans, "trace has no complete spans"
    for e in spans:  # Chrome trace-event schema: ints + complete-span fields
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int), e
        assert "name" in e and "ts" in e and e["dur"] >= 0, e
    # undo the Chrome int-tid mapping so the repro.obs helpers apply
    tid_name = {
        e["tid"]: e["args"]["name"]
        for e in events
        if e.get("ph") == "M" and e.get("name") == "thread_name"
    }
    raw = [
        dict(e, tid=tid_name.get(e["tid"], str(e["tid"])))
        for e in events
        if e.get("ph") in ("X", "i")
    ]
    for e in raw:
        if e["ph"] == "X" and e["tid"].startswith("rid"):
            assert "rid" in e["args"], e

    from repro.obs.trace import derive_request_metrics, span_coverage

    cov = span_coverage(raw)
    assert cov >= 0.95, f"span coverage {cov:.3f} < 0.95"
    per = derive_request_metrics(raw)
    assert len(per) == 4, sorted(per)

    rows = [json.loads(ln) for ln in open(metrics_path)]
    ttft_rows = [d for d in rows if d.get("name") == "serve.ttft_s"]
    assert ttft_rows, "metrics JSONL lacks the serve.ttft_s series"
    # span-derived TTFT vs the legacy per-request dict: within 1 ms
    for d in ttft_rows:
        rid = int(d["label"])
        assert abs(per[rid]["ttft_s"] - d["value"]) < 1e-3, (rid, d, per[rid])
    vals = [d["value"] for d in ttft_rows]
    assert abs(float(np.percentile(vals, 50)) - obs_doc["ttft_p50_s"]) < 1e-3
    gap = [d for d in rows if d.get("name") == "serve.decode_gap_s"]
    assert gap and gap[0]["count"] > 0, gap
    print(
        "obs smoke ok:",
        {
            "spans": len(spans),
            "coverage": round(cov, 4),
            "ttft_p50_s": obs_doc["ttft_p50_s"],
            "decode_gap_p99_s": obs_doc["decode_gap_p99_s"],
        },
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-1.5b", help="arch for every smoke")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("prefix", help="prefix-cache + preemption CLI parity")
    sub.add_parser("sampling", help="sampled serve reproducibility")
    ht = sub.add_parser("host-tier", help="forced-spill host-tier CLI parity")
    ht.add_argument("--host-cache-mb", type=float, default=64.0)
    ob = sub.add_parser("obs", help="trace/metrics schema + digest parity")
    ob.add_argument("--out-dir", default="obs-artifacts")
    args = ap.parse_args(argv)
    cmds = {
        "prefix": smoke_prefix,
        "sampling": smoke_sampling,
        "host-tier": smoke_host_tier,
        "obs": smoke_obs,
    }
    cmds[args.cmd](args)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
